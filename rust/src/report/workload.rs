//! Contended-latency study: each paper system serving N tenants at
//! once, idle-vs-contended per tenant (DESIGN.md §9). Rendered by
//! `agv workload`.

use crate::comm::Params;
use crate::topology::systems::SystemSpec;
use crate::topology::Topology;
use crate::util::error::Result;
use crate::util::{fmt_time, stats};
use crate::workload::{run_workload_with_baseline, WorkloadSpec};

/// One tenant's idle-vs-contended summary on one system.
#[derive(Clone, Debug)]
pub struct TenantRow {
    /// Tenant name from the spec.
    pub tenant: String,
    /// Library (or per-op candidate) labels the tenant ran — unique,
    /// first-use order, '+'-joined (one CSV column regardless of how
    /// many candidates an auto tenant flipped through).
    pub labels: String,
    /// Ops the tenant completed.
    pub ops: usize,
    /// Contended per-op latency percentiles (seconds).
    pub p50: f64,
    /// 95th percentile contended latency.
    pub p95: f64,
    /// 99th percentile contended latency.
    pub p99: f64,
    /// Idle-fabric per-op latency p50 (isolated composition).
    pub idle_p50: f64,
    /// Contended completion of the tenant's last op.
    pub completion: f64,
    /// Geomean of per-op contended/isolated latency ratios.
    pub slowdown: f64,
}

/// One system's section of the study.
#[derive(Clone, Debug)]
pub struct SystemSection {
    /// System name.
    pub system: String,
    /// Ranks each op spans.
    pub gpus: usize,
    /// Per-tenant rows, spec order.
    pub rows: Vec<TenantRow>,
    /// Shared-run makespan (seconds).
    pub makespan: f64,
    /// Achieved aggregate fabric utilization over the makespan.
    pub utilization: f64,
    /// Utilization of the hottest (link, direction).
    pub peak_utilization: f64,
    /// Total flows simulated.
    pub flows: usize,
}

/// Run one spec on one topology and fold the idle-vs-contended section.
pub fn section(topo: &Topology, spec: &WorkloadSpec, params: Params) -> Result<SystemSection> {
    // one planning pass feeds both the contended run and the baseline —
    // auto tenants pay the selector's candidate sims once, not twice
    let (contended, idle) = run_workload_with_baseline(topo, spec, params)?;
    let gpus = spec.tenants.iter().map(|t| t.stream.gpus()).max().unwrap_or(0);
    let rows = contended
        .tenants
        .iter()
        .zip(&idle)
        .map(|(t, iso)| {
            let lats = t.latencies();
            let ratios: Vec<f64> = lats
                .iter()
                .zip(iso)
                .map(|(&c, &i)| if i > 0.0 { c / i } else { 1.0 })
                .collect();
            // order-preserving global dedup; joined with '+' so the
            // field stays a single CSV column
            let mut labels: Vec<&str> = Vec::new();
            for op in &t.ops {
                if !labels.contains(&op.label.as_str()) {
                    labels.push(op.label.as_str());
                }
            }
            TenantRow {
                tenant: t.name.clone(),
                labels: labels.join("+"),
                ops: t.ops.len(),
                p50: stats::percentile(&lats, 50.0),
                p95: stats::percentile(&lats, 95.0),
                p99: stats::percentile(&lats, 99.0),
                idle_p50: stats::percentile(iso, 50.0),
                completion: t.completion,
                slowdown: stats::geomean(&ratios),
            }
        })
        .collect();
    Ok(SystemSection {
        system: topo.name.clone(),
        gpus,
        rows,
        makespan: contended.makespan,
        utilization: contended.utilization,
        peak_utilization: contended.peak_utilization,
        flows: contended.flows,
    })
}

/// The default study: the same spec shape on each system — paper
/// systems or parametric fabrics (sections fan out over the bounded
/// worker pool, results in system order). `mk_spec` receives the
/// system's GPU budget so specs can adapt rank counts.
pub fn study(
    systems: &[SystemSpec],
    params: Params,
    mk_spec: impl Fn(usize) -> WorkloadSpec + Sync,
) -> Result<Vec<SystemSection>> {
    let jobs: Vec<_> = systems
        .iter()
        .map(|&spec| {
            let mk = &mk_spec;
            move || {
                let topo = spec.build();
                let wspec = mk(topo.num_gpus());
                section(&topo, &wspec, params)
            }
        })
        .collect();
    crate::util::pool::parallel_map(jobs).into_iter().collect()
}

/// Render the study as text tables, one section per system.
pub fn render(sections: &[SystemSection]) -> String {
    let mut out = String::new();
    out.push_str(
        "WORKLOAD — concurrent Allgatherv tenants on a shared fabric (idle vs contended)\n",
    );
    for s in sections {
        out.push_str(&format!(
            "\n== {} @ {} GPUs/op — makespan {}, utilization {:.1}% (peak linkdir {:.1}%), {} flows ==\n",
            s.system,
            s.gpus,
            fmt_time(s.makespan),
            100.0 * s.utilization,
            100.0 * s.peak_utilization,
            s.flows
        ));
        out.push_str(&format!(
            "{:<10} {:<22} {:>4} {:>12} {:>12} {:>12} {:>12} {:>12} {:>9}\n",
            "tenant", "lib", "ops", "idle p50", "p50", "p95", "p99", "done", "slowdown"
        ));
        for r in &s.rows {
            out.push_str(&format!(
                "{:<10} {:<22} {:>4} {:>12} {:>12} {:>12} {:>12} {:>12} {:>8.2}x\n",
                r.tenant,
                r.labels,
                r.ops,
                fmt_time(r.idle_p50),
                fmt_time(r.p50),
                fmt_time(r.p95),
                fmt_time(r.p99),
                fmt_time(r.completion),
                r.slowdown,
            ));
        }
    }
    if !sections.is_empty() {
        let all: Vec<f64> =
            sections.iter().flat_map(|s| s.rows.iter().map(|r| r.slowdown)).collect();
        out.push_str(&format!(
            "\ncontention verdict: geomean tenant slowdown {:.2}x across {} tenant-system cells\n",
            stats::geomean(&all),
            all.len()
        ));
    }
    out
}

/// CSV form of the study (one row per tenant-system cell).
pub fn csv(sections: &[SystemSection]) -> String {
    let mut out = String::from(
        "system,gpus,tenant,lib,ops,idle_p50_s,p50_s,p95_s,p99_s,completion_s,slowdown,\
         makespan_s,utilization\n",
    );
    for s in sections {
        for r in &s.rows {
            out.push_str(&format!(
                "{},{},{},{},{},{:.9},{:.9},{:.9},{:.9},{:.9},{:.6},{:.9},{:.6}\n",
                s.system,
                s.gpus,
                r.tenant,
                r.labels,
                r.ops,
                r.idle_p50,
                r.p50,
                r.p95,
                r.p99,
                r.completion,
                r.slowdown,
                s.makespan,
                s.utilization,
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Library;
    use crate::workload::TenantLib;

    fn small_spec(gpus: usize) -> WorkloadSpec {
        WorkloadSpec::synthetic(
            2,
            2,
            gpus.min(4),
            TenantLib::Fixed(Library::Nccl),
            4 << 20,
            13,
        )
    }

    #[test]
    fn study_renders_all_systems_with_contention() {
        let secs = study(&SystemSpec::paper_all(), Params::default(), small_spec).unwrap();
        assert_eq!(secs.len(), 3);
        let text = render(&secs);
        for k in SystemSpec::paper_all() {
            assert!(text.contains(k.name().as_str()), "{k:?} missing:\n{text}");
        }
        assert!(text.contains("WORKLOAD"));
        assert!(text.contains("slowdown"));
        for s in &secs {
            for r in &s.rows {
                assert!(r.p50 > 0.0 && r.p99 >= r.p50);
                assert!(r.slowdown >= 1.0 - 1e-6, "{}: free lunch {}", s.system, r.slowdown);
            }
        }
        let c = csv(&secs);
        assert_eq!(c.lines().count(), 1 + 3 * 2);
        assert!(c.starts_with("system,"));
    }

    #[test]
    fn study_runs_on_parametric_fabrics() {
        // the contended-tenant study must work unchanged on the scale
        // fabrics: a small rail-optimized pod and a fat-tree
        let systems = [
            SystemSpec::MultiPlanePod { nodes: 2, gpus: 4, rails: 2 },
            SystemSpec::FatTree { k: 4 },
        ];
        let secs = study(&systems, Params::default(), small_spec).unwrap();
        assert_eq!(secs.len(), 2);
        assert_eq!(secs[0].system, "pod-2x4x2");
        assert_eq!(secs[1].system, "fat-tree-k4");
        for s in &secs {
            assert!(s.makespan > 0.0 && s.flows > 0, "{}: empty section", s.system);
            // fabric names must stay CSV-safe (one column per field)
            assert!(!s.system.contains(','), "{}", s.system);
        }
    }

    #[test]
    fn section_is_deterministic() {
        let topo = SystemSpec::parse("dgx1").unwrap().build();
        let spec = small_spec(8);
        let a = section(&topo, &spec, Params::default()).unwrap();
        let b = section(&topo, &spec, Params::default()).unwrap();
        assert_eq!(render(&[a]), render(&[b]));
    }
}
