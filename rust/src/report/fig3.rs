//! Fig. 3: ReFacTo total communication time across the data sets,
//! systems, libraries and GPU counts.

use crate::comm::{Library, Params};
use crate::cpals::comm_model::{refacto_comm, RefactoReport, DEFAULT_ITERS};
use crate::tensor::datasets;
use crate::topology::systems::SystemKind;
use crate::util::plot::{bar_chart, Series};

/// One Fig. 3 panel: a system at a GPU count, all data sets x libraries.
#[derive(Clone, Debug)]
pub struct Fig3Panel {
    /// System of this panel.
    pub system: SystemKind,
    /// GPU count of this panel.
    pub gpus: usize,
    /// reports indexed \[dataset\]\[library\]
    pub reports: Vec<Vec<RefactoReport>>,
}

/// The GPU counts plotted per system (as in the paper's Fig. 3 panels).
pub fn gpu_counts(system: SystemKind) -> Vec<usize> {
    crate::osu::gpu_counts(system)
}

/// Build all panels (parallel over panels).
pub fn panels(iters: usize) -> Vec<Fig3Panel> {
    let mut jobs: Vec<Box<dyn FnOnce() -> Fig3Panel + Send>> = Vec::new();
    for system in SystemKind::all() {
        for gpus in gpu_counts(system) {
            jobs.push(Box::new(move || {
                let topo = system.build();
                let reports = datasets::all()
                    .iter()
                    .map(|d| {
                        Library::all()
                            .into_iter()
                            .map(|lib| {
                                refacto_comm(&topo, lib, Params::default(), d, gpus, iters)
                            })
                            .collect()
                    })
                    .collect();
                Fig3Panel { system, gpus, reports }
            }));
        }
    }
    super::parallel_map(jobs)
}

/// Panels at the paper's default iteration count.
pub fn default_panels() -> Vec<Fig3Panel> {
    panels(DEFAULT_ITERS)
}

impl Fig3Panel {
    /// Total communication time of one (data set, library) bar.
    pub fn time(&self, dataset: &str, lib: Library) -> f64 {
        let di = datasets::all()
            .iter()
            .position(|d| d.name == dataset)
            .expect("unknown dataset");
        self.reports[di]
            .iter()
            .find(|r| r.library == lib)
            .unwrap()
            .total_time
    }
}

/// ASCII rendering.
pub fn render(panels: &[Fig3Panel]) -> String {
    let labels: Vec<&str> = datasets::all().iter().map(|d| d.name).collect();
    let mut out = String::from(
        "FIG. 3 — ReFacTo total communication time (10 CP-ALS iterations)\n\n",
    );
    for p in panels {
        let series: Vec<Series> = Library::all()
            .into_iter()
            .map(|lib| {
                Series::new(
                    lib.name(),
                    labels
                        .iter()
                        .enumerate()
                        .map(|(i, d)| (i as f64, p.time(d, lib)))
                        .collect(),
                )
            })
            .collect();
        out.push_str(&bar_chart(
            &format!("{} — {} GPUs", p.system.name(), p.gpus),
            &labels,
            &series,
            48,
        ));
        out.push('\n');
    }
    out
}

/// CSV: system,gpus,dataset,library,total_seconds
pub fn csv(panels: &[Fig3Panel]) -> String {
    let mut out = String::from("system,gpus,dataset,library,total_seconds\n");
    for p in panels {
        for row in &p.reports {
            for r in row {
                out.push_str(&format!(
                    "{},{},{},{},{:.6}\n",
                    p.system.name(), p.gpus, r.dataset, r.library.name(), r.total_time
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panels_cover_grid() {
        let ps = panels(1);
        assert_eq!(ps.len(), 8);
        for p in &ps {
            assert_eq!(p.reports.len(), 4);
            assert_eq!(p.reports[0].len(), 3);
        }
    }

    #[test]
    fn lookup_and_render() {
        let ps = panels(1);
        let t = ps[0].time("NETFLIX", Library::Nccl);
        assert!(t > 0.0);
        let txt = render(&ps[..1]);
        assert!(txt.contains("NETFLIX"));
        let c = csv(&ps);
        assert_eq!(c.trim().lines().count(), 1 + 8 * 4 * 3);
    }
}
