//! Seeded PRNG: xoshiro256++ with splitmix64 seeding.
//!
//! Deterministic across runs and platforms — every synthetic data set,
//! property test and simulation perturbation in this crate derives from an
//! explicit seed so experiments are exactly reproducible.

/// xoshiro256++ generator (public-domain algorithm by Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a generator (splitmix64-expanded into the xoshiro state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> uniform double in [0, 1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn gen_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Derive an independent child generator (for per-rank/per-case seeds).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn unit_uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn fork_independence() {
        let mut base = Rng::new(9);
        let mut c1 = base.fork(1);
        let mut c2 = base.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
