//! Minimal error type with human-readable context chains (anyhow is
//! unavailable offline).
//!
//! Mirrors the subset of `anyhow` the crate uses: the [`anyhow!`] and
//! [`bail!`] macros, a [`Context`] extension trait with
//! `context`/`with_context`, and an [`Error`] whose alternate `{:#}`
//! Display prints the full cause chain (`outer: inner: root`).
//!
//! [`anyhow!`]: crate::anyhow
//! [`bail!`]: crate::bail

use std::fmt;

/// An error carrying a chain of context layers, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap this error with an outer context layer.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context/cause layers, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain on one line, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket conversion (what makes `?` work on io/json/xla errors)
// cannot conflict with the reflexive `From<Error> for Error`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Alias defaulting the error type to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension adding context layers to any `Result` whose error converts
/// into [`Error`].
pub trait Context<T> {
    /// Wrap the error (if any) with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Wrap the error (if any) with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string (like `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($fmt:tt)+) => {
        $crate::util::error::Error::msg(format!($($fmt)+))
    };
}

/// Return early with an [`Error`] built from a format string (like
/// `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($fmt:tt)+) => {
        return Err($crate::anyhow!($($fmt)+).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading meta.json")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading meta.json");
        assert_eq!(format!("{e:#}"), "reading meta.json: no such file");
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("artifact `{}` missing", "fit");
        assert_eq!(format!("{e}"), "artifact `fit` missing");
        fn f() -> Result<()> {
            bail!("bad {}", 7);
        }
        assert_eq!(format!("{:#}", f().unwrap_err()), "bad 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn chain_preserves_layers() {
        let e = Error::msg("root").context("mid").context("outer");
        let layers: Vec<&str> = e.chain().collect();
        assert_eq!(layers, vec!["outer", "mid", "root"]);
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"));
    }
}
