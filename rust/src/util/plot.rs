//! ASCII rendering of the paper's figures: log-log line charts (Fig. 2)
//! and grouped bar charts (Fig. 3), plus CSV emission for external
//! plotting.

/// A named series of (x, y) points.
#[derive(Clone, Debug)]
pub struct Series {
    /// Legend label.
    pub name: String,
    /// The (x, y) samples.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// Build a series from a label and points.
    pub fn new(name: impl Into<String>, points: Vec<(f64, f64)>) -> Series {
        Series { name: name.into(), points }
    }
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@'];

/// Render series on a log-x / log-y grid (the paper's Fig. 2 axes).
pub fn log_log_chart(title: &str, xlabel: &str, ylabel: &str, series: &[Series],
                     width: usize, height: usize) -> String {
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for s in series {
        pts.extend(s.points.iter().filter(|(x, y)| *x > 0.0 && *y > 0.0));
    }
    if pts.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x.ln());
        x1 = x1.max(x.ln());
        y0 = y0.min(y.ln());
        y1 = y1.max(y.ln());
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in s.points.iter().filter(|(x, y)| *x > 0.0 && *y > 0.0) {
            let cx = ((x.ln() - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
            let cy = ((y.ln() - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx] = mark;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    out.push_str(&format!("  y: {ylabel} (log)\n"));
    for row in &grid {
        out.push_str("  |");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str("  +");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("   x: {xlabel} (log)\n"));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("   {} {}\n", MARKS[si % MARKS.len()], s.name));
    }
    out
}

/// Grouped horizontal bar chart (the paper's Fig. 3 layout): one group per
/// label, one bar per series.
pub fn bar_chart(title: &str, labels: &[&str], series: &[Series], width: usize) -> String {
    let max = series
        .iter()
        .flat_map(|s| s.points.iter().map(|p| p.1))
        .fold(0.0_f64, f64::max);
    let mut out = format!("{title}\n");
    if max <= 0.0 {
        out.push_str("(no data)\n");
        return out;
    }
    for (li, label) in labels.iter().enumerate() {
        out.push_str(&format!("  {label}\n"));
        for (si, s) in series.iter().enumerate() {
            let v = s.points.get(li).map(|p| p.1).unwrap_or(0.0);
            let n = ((v / max) * width as f64).round() as usize;
            out.push_str(&format!(
                "    {:<10} |{}{} {}\n",
                s.name,
                MARKS[si % MARKS.len()].to_string().repeat(n.max(if v > 0.0 { 1 } else { 0 })),
                "",
                crate::util::fmt_time(v),
            ));
        }
    }
    out
}

/// CSV emission: header `x,<name1>,<name2>,...`, one row per x of the
/// first series (series must share x grids).
pub fn to_csv(series: &[Series]) -> String {
    let mut out = String::from("x");
    for s in series {
        out.push(',');
        out.push_str(&s.name);
    }
    out.push('\n');
    if series.is_empty() {
        return out;
    }
    for (i, &(x, _)) in series[0].points.iter().enumerate() {
        out.push_str(&format!("{x}"));
        for s in series {
            match s.points.get(i) {
                Some(&(_, y)) => out.push_str(&format!(",{y}")),
                None => out.push(','),
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chart_contains_marks_and_legend() {
        let s = vec![
            Series::new("mpi", vec![(4096.0, 1e-3), (1e6, 1e-2)]),
            Series::new("nccl", vec![(4096.0, 5e-4), (1e6, 2e-2)]),
        ];
        let c = log_log_chart("Fig2", "bytes", "s", &s, 40, 10);
        assert!(c.contains('*'));
        assert!(c.contains('o'));
        assert!(c.contains("mpi"));
        assert!(c.contains("nccl"));
    }

    #[test]
    fn chart_empty_data() {
        let c = log_log_chart("t", "x", "y", &[], 10, 5);
        assert!(c.contains("no data"));
    }

    #[test]
    fn csv_shape() {
        let s = vec![
            Series::new("a", vec![(1.0, 2.0), (3.0, 4.0)]),
            Series::new("b", vec![(1.0, 5.0), (3.0, 6.0)]),
        ];
        let csv = to_csv(&s);
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines[0], "x,a,b");
        assert_eq!(lines[1], "1,2,5");
        assert_eq!(lines[2], "3,4,6");
    }

    #[test]
    fn bars_render_each_label() {
        let s = vec![Series::new("mpi", vec![(0.0, 1.0), (1.0, 2.0)])];
        let c = bar_chart("Fig3", &["NETFLIX", "AMAZON"], &s, 20);
        assert!(c.contains("NETFLIX"));
        assert!(c.contains("AMAZON"));
    }
}
