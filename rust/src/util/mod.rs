//! Self-contained utilities.
//!
//! The offline build environment only ships the `xla` crate's vendored
//! dependency closure — no `rand`, `criterion`, `proptest` or `clap` — so
//! this module provides the small, tested equivalents the rest of the
//! crate needs: a seeded PRNG, summary statistics, a benchmark harness
//! (used by every `cargo bench` target), a bounded worker pool for grid
//! fan-out, a property-test runner, a CLI parser and ASCII plotting for
//! figure reproduction.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod plot;
pub mod pool;
pub mod prng;
pub mod prop;
pub mod stats;

/// Pretty-print a byte count the way the paper's axes do (4KB, 1MB, ...).
pub fn fmt_bytes(bytes: u64) -> String {
    const KB: u64 = 1024;
    const MB: u64 = 1024 * KB;
    const GB: u64 = 1024 * MB;
    if bytes >= GB && bytes % GB == 0 {
        format!("{}GB", bytes / GB)
    } else if bytes >= MB && bytes % MB == 0 {
        format!("{}MB", bytes / MB)
    } else if bytes >= KB && bytes < MB && bytes % KB == 0 {
        format!("{}KB", bytes / KB)
    } else if bytes >= MB {
        format!("{:.1}MB", bytes as f64 / MB as f64)
    } else if bytes >= KB {
        format!("{:.1}KB", bytes as f64 / KB as f64)
    } else {
        format!("{bytes}B")
    }
}

/// Format seconds with a sensible unit (matches the paper's ms/s axes).
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.1}us", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_exact_units() {
        assert_eq!(fmt_bytes(4096), "4KB");
        assert_eq!(fmt_bytes(1024 * 1024), "1MB");
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(3 * 1024 * 1024 * 1024), "3GB");
    }

    #[test]
    fn fmt_bytes_fractional() {
        assert_eq!(fmt_bytes(1536), "1.5KB");
        assert_eq!(fmt_bytes(1024 * 1024 + 512 * 1024), "1.5MB");
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.5), "2.500s");
        assert_eq!(fmt_time(0.0125), "12.500ms");
        assert_eq!(fmt_time(42e-6), "42.0us");
    }
}
