//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [positional...]`.
//! Numeric accessors return a clean [`crate::util::error::Error`] on
//! malformed values — the binary surfaces these as usage errors (exit
//! 2) instead of panicking.

use std::collections::BTreeMap;

use crate::anyhow;
use crate::util::error::Result;

/// Parsed command line: subcommand, positionals, `--key value` options
/// and bare `--flag`s.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First bare argument, if any.
    pub subcommand: Option<String>,
    /// Bare arguments after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s (no value).
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv\[0\]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // --key=value, --key value, or --flag
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments (argv\[0\] skipped).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Is the bare `--name` flag present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Value of `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Value of `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `--name` parsed as `usize` (clean usage error on junk).
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got `{v}`")),
            None => Ok(default),
        }
    }

    /// `--name` parsed as `u64` (clean usage error on junk).
    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got `{v}`")),
            None => Ok(default),
        }
    }

    /// `--name` parsed as `f64` (clean usage error on junk; rejects
    /// NaN/infinite spellings — no flag means anything non-finite).
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            Some(v) => v
                .parse::<f64>()
                .ok()
                .filter(|x| x.is_finite())
                .ok_or_else(|| anyhow!("--{name} expects a finite number, got `{v}`")),
            None => Ok(default),
        }
    }
}

/// Parse byte sizes like "4KB", "1MB", "16", "512mb".
pub fn parse_bytes(s: &str) -> Option<u64> {
    let t = s.trim().to_ascii_uppercase();
    let (num, mult) = if let Some(n) = t.strip_suffix("GB") {
        (n, 1024 * 1024 * 1024)
    } else if let Some(n) = t.strip_suffix("MB") {
        (n, 1024 * 1024)
    } else if let Some(n) = t.strip_suffix("KB") {
        (n, 1024)
    } else if let Some(n) = t.strip_suffix('B') {
        (n, 1)
    } else {
        (t.as_str(), 1)
    };
    num.trim().parse::<f64>().ok().map(|v| (v * mult as f64) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["osu", "--system", "dgx1", "--gpus", "8", "--csv"]);
        assert_eq!(a.subcommand.as_deref(), Some("osu"));
        assert_eq!(a.get("system"), Some("dgx1"));
        assert_eq!(a.get_usize("gpus", 2).unwrap(), 8);
        assert!(a.flag("csv"));
    }

    #[test]
    fn key_equals_value() {
        let a = parse(&["run", "--seed=42"]);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 42);
    }

    #[test]
    fn malformed_numerics_are_clean_errors() {
        let a = parse(&["collective", "--chunks", "many", "--gap", "soon", "--seed", "-1"]);
        let e = a.get_usize("chunks", 1).unwrap_err();
        assert!(e.to_string().contains("--chunks expects an integer"), "{e}");
        let e = a.get_f64("gap", 0.0).unwrap_err();
        assert!(e.to_string().contains("--gap expects a finite number"), "{e}");
        assert!(a.get_u64("seed", 0).is_err(), "negative u64");
        // non-finite spellings parse as f64 but are rejected as flags
        let b = parse(&["x", "--gap", "NaN"]);
        assert!(b.get_f64("gap", 0.0).is_err(), "NaN gap");
        let c = parse(&["x", "--gap", "inf"]);
        assert!(c.get_f64("gap", 0.0).is_err(), "inf gap");
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&["table1", "netflix", "amazon"]);
        assert_eq!(a.positional, vec!["netflix", "amazon"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert!(a.subcommand.is_none());
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("y", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn parse_bytes_units() {
        assert_eq!(parse_bytes("4KB"), Some(4096));
        assert_eq!(parse_bytes("1MB"), Some(1024 * 1024));
        assert_eq!(parse_bytes("16"), Some(16));
        assert_eq!(parse_bytes("0.5MB"), Some(512 * 1024));
        assert_eq!(parse_bytes("2gb"), Some(2 * 1024 * 1024 * 1024));
        assert_eq!(parse_bytes("junk"), None);
    }
}
