//! Minimal JSON parser (serde is unavailable offline).
//!
//! Supports the subset emitted by `python/compile/aot.py`'s `meta.json`:
//! objects, arrays, strings (no escapes beyond \" \\ \/ \n \t), numbers,
//! booleans and null. Strict enough to fail loudly on malformed input.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (all JSON numbers are f64 here).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys for deterministic iteration).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset it occurred at.
#[derive(Debug)]
pub struct JsonError {
    /// Byte position in the input.
    pub pos: usize,
    /// What was expected/found.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects/missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key-value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize back to JSON text (compact, deterministic: object keys
    /// come out in `BTreeMap` order). Non-finite numbers — which JSON
    /// cannot represent — serialize as `null`. Round-trips through
    /// [`Json::parse`]; `BENCH_*.json` emission uses this.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // `{}` on f64 prints the shortest exact round-trip form
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32));
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).render_into(out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Build a [`Json::Obj`] from key/value pairs (serialization helper).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.pos += 1;
                    out.push(match c {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'u' => {
                            // \uXXXX basic-plane escapes (no surrogate pairs)
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("eof in \\u escape"));
                            }
                            let digits = &self.b[self.pos..self.pos + 4];
                            // from_str_radix alone would accept "+041"
                            if !digits.iter().all(|b| b.is_ascii_hexdigit()) {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(digits)
                                .ok()
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            char::from_u32(hex)
                                .ok_or_else(|| self.err("surrogate \\u escape unsupported"))?
                        }
                        _ => return Err(self.err("unsupported escape")),
                    });
                }
                Some(c) => {
                    // copy raw UTF-8 bytes through
                    let start = self.pos;
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos += len;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
                None => return Err(self.err("eof in string")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_like_document() {
        let doc = r#"{
 "als_sweep_small": {
  "file": "als_sweep_small.hlo.txt",
  "inputs": [{"shape": [2048], "dtype": "f32"}, {"shape": [], "dtype": "f32"}],
  "outputs": [{"shape": [128, 16], "dtype": "f32"}],
  "config": {"name": "small", "rank": 16}
 }
}"#;
        let j = Json::parse(doc).unwrap();
        let art = j.get("als_sweep_small").unwrap();
        assert_eq!(art.get("file").unwrap().as_str(), Some("als_sweep_small.hlo.txt"));
        let ins = art.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins.len(), 2);
        let shape = ins[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize(), Some(2048));
        assert_eq!(ins[1].get("shape").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(art.get("config").unwrap().get("rank").unwrap().as_usize(), Some(16));
    }

    #[test]
    fn scalars_and_errors() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""a\nb""#).unwrap().as_str(), Some("a\nb"));
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn render_round_trips() {
        for doc in [
            r#"{"a":[1,2.5,-3],"b":"x\ny","c":{"nested":true},"d":null}"#,
            "[]",
            "{}",
            r#""quote \" backslash \\ tab \t""#,
            "-1.5e2",
        ] {
            let v = Json::parse(doc).unwrap();
            let rendered = v.render();
            assert_eq!(Json::parse(&rendered).unwrap(), v, "doc: {doc}");
        }
    }

    #[test]
    fn render_is_compact_and_sorted() {
        let v = obj(vec![
            ("zeta", Json::Num(1.0)),
            ("alpha", Json::Str("s".into())),
        ]);
        assert_eq!(v.render(), r#"{"alpha":"s","zeta":1}"#);
    }

    #[test]
    fn render_nonfinite_as_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
        assert_eq!(Json::parse("\"\\u000a\"").unwrap().as_str(), Some("\n"));
        assert!(Json::parse("\"\\ud800\"").is_err());
        assert!(Json::parse("\"\\u00g1\"").is_err());
        assert!(Json::parse("\"\\u+041\"").is_err());
    }
}
