//! Summary statistics: mean, std, CV (Table I's irregularity measure),
//! min/max, percentiles.

/// Summary of a sample of non-negative measurements (message sizes, times).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation (ddof = 0).
    pub std: f64,
    /// Coefficient of variation = std / mean — the paper's Table I
    /// "Msg Size CV" irregularity measure.
    pub cv: f64,
    /// Smallest observation.
    pub min: f64,
    /// Largest observation.
    pub max: f64,
    /// Sum of all observations.
    pub sum: f64,
}

impl Summary {
    /// Population statistics (ddof = 0), matching the paper's CV usage.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let sum: f64 = xs.iter().sum();
        let mean = sum / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let std = var.sqrt();
        let cv = if mean != 0.0 { std / mean } else { 0.0 };
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Summary { n, mean, std, cv, min, max, sum }
    }

    /// Max/min ratio — the paper's "25,400x difference" style metric.
    pub fn spread(&self) -> f64 {
        if self.min > 0.0 {
            self.max / self.min
        } else {
            f64::INFINITY
        }
    }
}

/// q-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=100.0).contains(&q));
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q / 100.0 * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Geometric mean (used for the paper's "1.2x faster on average" claims).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_constant_sample() {
        let s = Summary::of(&[5.0, 5.0, 5.0]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.cv, 0.0);
        assert_eq!(s.spread(), 1.0);
    }

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean - 5.0).abs() < 1e-12);
        assert!((s.std - 2.0).abs() < 1e-12); // classic population-std example
        assert!((s.cv - 0.4).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn spread_matches_paper_metric() {
        // DELICIOUS-like: 0.02MB min, 508MB max -> 25,400x
        let s = Summary::of(&[0.02, 508.0]);
        assert!((s.spread() - 25_400.0).abs() < 1.0);
    }

    #[test]
    fn percentile_interpolation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }
}
