//! Summary statistics: mean, std, CV (Table I's irregularity measure),
//! min/max, percentiles.
//!
//! Every sorter here uses [`f64::total_cmp`] (never a panicking
//! `partial_cmp().unwrap()`), and the fallible entry points
//! ([`Summary::try_of`], [`try_percentile`]) reject empty and
//! non-finite samples with a clean [`crate::util::error::Error`] — a
//! NaN latency sample surfaces as a diagnosable error in the SLO
//! reports instead of a sort panic deep inside the percentile kernel.

use crate::anyhow;
use crate::util::error::Result;

/// Summary of a sample of non-negative measurements (message sizes, times).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation (ddof = 0).
    pub std: f64,
    /// Coefficient of variation = std / mean — the paper's Table I
    /// "Msg Size CV" irregularity measure.
    pub cv: f64,
    /// Smallest observation.
    pub min: f64,
    /// Largest observation.
    pub max: f64,
    /// Sum of all observations.
    pub sum: f64,
}

impl Summary {
    /// Population statistics (ddof = 0), matching the paper's CV usage.
    /// Rejects empty samples and non-finite observations cleanly.
    pub fn try_of(xs: &[f64]) -> Result<Summary> {
        if xs.is_empty() {
            return Err(anyhow!("Summary::of on empty sample"));
        }
        if let Some(bad) = xs.iter().find(|x| !x.is_finite()) {
            return Err(anyhow!("Summary::of on non-finite sample value {bad}"));
        }
        let n = xs.len();
        let sum: f64 = xs.iter().sum();
        let mean = sum / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let std = var.sqrt();
        let cv = if mean != 0.0 { std / mean } else { 0.0 };
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Ok(Summary { n, mean, std, cv, min, max, sum })
    }

    /// [`Summary::try_of`] for infallible call sites; panics with the
    /// same clean message on empty or non-finite samples.
    pub fn of(xs: &[f64]) -> Summary {
        match Summary::try_of(xs) {
            Ok(s) => s,
            Err(e) => panic!("{e:#}"),
        }
    }

    /// Max/min ratio — the paper's "25,400x difference" style metric.
    pub fn spread(&self) -> f64 {
        if self.min > 0.0 {
            self.max / self.min
        } else {
            f64::INFINITY
        }
    }
}

/// q-th percentile (0..=100) by linear interpolation on a sorted copy
/// (total order via [`f64::total_cmp`]). Rejects empty samples,
/// out-of-range ranks, and non-finite observations cleanly.
pub fn try_percentile(xs: &[f64], q: f64) -> Result<f64> {
    if xs.is_empty() {
        return Err(anyhow!("percentile of empty sample"));
    }
    if !(0.0..=100.0).contains(&q) {
        return Err(anyhow!("percentile rank {q} outside 0..=100"));
    }
    if let Some(bad) = xs.iter().find(|x| !x.is_finite()) {
        return Err(anyhow!("percentile over non-finite sample value {bad}"));
    }
    let mut s = xs.to_vec();
    s.sort_by(f64::total_cmp);
    let pos = q / 100.0 * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    Ok(if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    })
}

/// [`try_percentile`] for infallible call sites; panics with the same
/// clean message on invalid input.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    match try_percentile(xs, q) {
        Ok(v) => v,
        Err(e) => panic!("{e:#}"),
    }
}

/// Geometric mean (used for the paper's "1.2x faster on average" claims).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_constant_sample() {
        let s = Summary::of(&[5.0, 5.0, 5.0]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.cv, 0.0);
        assert_eq!(s.spread(), 1.0);
    }

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean - 5.0).abs() < 1e-12);
        assert!((s.std - 2.0).abs() < 1e-12); // classic population-std example
        assert!((s.cv - 0.4).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn spread_matches_paper_metric() {
        // DELICIOUS-like: 0.02MB min, 508MB max -> 25,400x
        let s = Summary::of(&[0.02, 508.0]);
        assert!((s.spread() - 25_400.0).abs() < 1.0);
    }

    #[test]
    fn percentile_interpolation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn non_finite_samples_are_clean_errors() {
        // pre-fix: partial_cmp().unwrap() panicked inside sort on NaN
        let err = try_percentile(&[1.0, f64::NAN, 3.0], 50.0).unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "{err:#}");
        let err = try_percentile(&[1.0, f64::INFINITY], 50.0).unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "{err:#}");
        let err = Summary::try_of(&[0.0, f64::NEG_INFINITY]).unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "{err:#}");
        let err = try_percentile(&[], 50.0).unwrap_err();
        assert!(format!("{err:#}").contains("empty"), "{err:#}");
        let err = try_percentile(&[1.0], 101.0).unwrap_err();
        assert!(format!("{err:#}").contains("outside"), "{err:#}");
        // finite inputs unaffected by the total_cmp switch
        assert_eq!(try_percentile(&[3.0, 1.0, 2.0], 100.0).unwrap(), 3.0);
        assert_eq!(try_percentile(&[-0.0, 0.0], 0.0).unwrap(), -0.0);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn percentile_nan_panics_with_clean_message() {
        let _ = percentile(&[f64::NAN], 50.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }
}
