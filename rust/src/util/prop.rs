//! Property-test runner (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure against `cases`
//! independently-seeded PRNGs and panics with the failing seed so a
//! regression can be replayed deterministically with `check_seed`.

use super::prng::Rng;

/// Run `property` for `cases` random cases. The closure receives a seeded
/// generator; return `Err(msg)` (or panic) to fail. On failure the seed is
/// reported so the case can be replayed.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xA6C0_5EED ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seed<F>(name: &str, seed: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = property(&mut rng) {
        panic!("property `{name}` failed (seed {seed:#x}): {msg}");
    }
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 32, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 32);
    }

    #[test]
    #[should_panic(expected = "property `bad` failed")]
    fn failing_property_reports_seed() {
        check("bad", 8, |rng| {
            let x = rng.gen_range(100);
            if x < 1000 {
                Err(format!("x={x}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn macro_returns_err() {
        fn prop(v: u64) -> Result<(), String> {
            prop_assert!(v < 10, "v too big: {v}");
            Ok(())
        }
        assert!(prop(5).is_ok());
        assert!(prop(50).is_err());
    }
}
