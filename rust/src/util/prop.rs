//! Property-test runner (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure against `cases`
//! independently-seeded PRNGs and panics with the failing seed so a
//! regression can be replayed deterministically with `check_seed`.

use super::prng::Rng;

/// Run `property` for `cases` random cases. The closure receives a seeded
/// generator; return `Err(msg)` (or panic) to fail. On failure the seed is
/// reported so the case can be replayed.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xA6C0_5EED ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seed<F>(name: &str, seed: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = property(&mut rng) {
        panic!("property `{name}` failed (seed {seed:#x}): {msg}");
    }
}

/// Count-vector generators mirroring the paper's §IV tensor
/// irregularity regimes, for reuse across property tests (the
/// schedule-conformance harness and `proptests.rs` both draw from
/// these). All sizes are bytes; every generator is deterministic in
/// the seeded [`Rng`].
pub mod counts {
    use crate::util::prng::Rng;

    /// Regular vector: every rank contributes `base` bytes (the OSU
    /// fixed-size shape).
    pub fn regular(p: usize, base: u64) -> Vec<u64> {
        vec![base; p]
    }

    /// Power-law skewed vector (AMAZON/NETFLIX-style): rank shares fall
    /// off as `1/(i+1)^a` with a random exponent, scattered over random
    /// ranks, topping out near `max`.
    pub fn skewed(rng: &mut Rng, p: usize, max: u64) -> Vec<u64> {
        let a = rng.gen_f64(0.5, 2.0);
        let mut v: Vec<u64> = (0..p)
            .map(|i| ((max as f64) / ((i + 1) as f64).powf(a)).max(1.0) as u64)
            .collect();
        rng.shuffle(&mut v);
        v
    }

    /// Zero-heavy vector (DELICIOUS-style min ≈ 0): roughly half the
    /// ranks contribute nothing at all.
    pub fn zero_heavy(rng: &mut Rng, p: usize, max: u64) -> Vec<u64> {
        (0..p)
            .map(|_| if rng.gen_range(2) == 0 { 0 } else { 1 + rng.gen_range(max) })
            .collect()
    }

    /// Single hot rank (NELL-1-style dominant block): one rank holds a
    /// message orders of magnitude above the rest.
    pub fn single_hot(rng: &mut Rng, p: usize, hot: u64) -> Vec<u64> {
        let mut v: Vec<u64> = (0..p).map(|_| 1 + rng.gen_range((hot / 256).max(1))).collect();
        let i = rng.gen_range(p as u64) as usize;
        v[i] = hot;
        v
    }

    /// Random irregularity regime: one of the shapes above, uniformly.
    pub fn irregular(rng: &mut Rng, p: usize, max: u64) -> Vec<u64> {
        match rng.gen_range(4) {
            0 => regular(p, 1 + rng.gen_range(max)),
            1 => skewed(rng, p, max),
            2 => zero_heavy(rng, p, max),
            _ => single_hot(rng, p, max),
        }
    }

    /// Reduction segment widths for allreduce/bcast specs: the reduced
    /// vector cut into P ragged pieces. Draws an irregularity regime
    /// like [`irregular`] but guarantees at least one non-zero segment
    /// (a fully empty reduce vector is a different degenerate, covered
    /// by explicit zero-count tests).
    pub fn reduce_widths(rng: &mut Rng, p: usize, max: u64) -> Vec<u64> {
        let mut v = irregular(rng, p, max);
        if v.iter().all(|&c| c == 0) {
            v[rng.gen_range(p as u64) as usize] = 1 + rng.gen_range(max);
        }
        v
    }

    /// Src-major flattened p×p alltoallv count matrix with a zero
    /// diagonal and per-row §IV irregularity regimes (each source rank
    /// independently regular / skewed / zero-heavy / single-hot toward
    /// its peers), so rows and columns stay mutually consistent: entry
    /// `src * p + dst` is what src sends dst.
    pub fn alltoallv_matrix(rng: &mut Rng, p: usize, max: u64) -> Vec<u64> {
        let mut m = vec![0u64; p * p];
        for src in 0..p {
            let row = irregular(rng, p, max);
            for dst in 0..p {
                if dst != src {
                    m[src * p + dst] = row[dst];
                }
            }
        }
        m
    }
}

/// Random small fabric specs for the topology property tests: sizes
/// stay modest (a few hundred devices at most) so each proptest case
/// builds and routes in microseconds, while still sweeping every
/// parameter the builders branch on. Deterministic in the seeded
/// [`Rng`].
pub mod fabrics {
    use crate::topology::systems::SystemSpec;
    use crate::util::prng::Rng;

    /// Random even fat-tree arity: k ∈ {2, 4, 6, 8}.
    pub fn fat_tree_spec(rng: &mut Rng) -> SystemSpec {
        SystemSpec::FatTree { k: 2 * (1 + rng.gen_range(4) as usize) }
    }

    /// Random dragonfly: a ∈ 1..=4 routers/group, p ∈ 1..=3 hosts/router,
    /// h ∈ 1..=3 global links/router (so 2..=234 hosts).
    pub fn dragonfly_spec(rng: &mut Rng) -> SystemSpec {
        SystemSpec::Dragonfly {
            a: 1 + rng.gen_range(4) as usize,
            p: 1 + rng.gen_range(3) as usize,
            h: 1 + rng.gen_range(3) as usize,
        }
    }

    /// Random rail-optimized pod: nodes ∈ 1..=6, gpus ∈ 1..=8,
    /// rails ∈ 1..=gpus (more rails than GPUs never adds a distinct
    /// route, so the generator keeps the interesting range).
    pub fn pod_spec(rng: &mut Rng) -> SystemSpec {
        let gpus = 1 + rng.gen_range(8) as usize;
        SystemSpec::MultiPlanePod {
            nodes: 1 + rng.gen_range(6) as usize,
            gpus,
            rails: 1 + rng.gen_range(gpus as u64) as usize,
        }
    }

    /// Any fabric family, uniformly.
    pub fn any_fabric(rng: &mut Rng) -> SystemSpec {
        match rng.gen_range(3) {
            0 => fat_tree_spec(rng),
            1 => dragonfly_spec(rng),
            _ => pod_spec(rng),
        }
    }
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 32, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 32);
    }

    #[test]
    #[should_panic(expected = "property `bad` failed")]
    fn failing_property_reports_seed() {
        check("bad", 8, |rng| {
            let x = rng.gen_range(100);
            if x < 1000 {
                Err(format!("x={x}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn count_generators_have_their_shapes() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(99);
        let p = 16;
        assert_eq!(counts::regular(p, 4096), vec![4096u64; p]);
        let sk = counts::skewed(&mut rng, p, 1 << 20);
        assert_eq!(sk.len(), p);
        assert!(sk.iter().all(|&c| c >= 1));
        assert_eq!(*sk.iter().max().unwrap(), 1 << 20);
        let zh = counts::zero_heavy(&mut rng, 64, 1 << 20);
        let zeros = zh.iter().filter(|&&c| c == 0).count();
        assert!(zeros > 8 && zeros < 56, "zeros={zeros}");
        let hot = counts::single_hot(&mut rng, p, 512 << 20);
        assert_eq!(hot.iter().filter(|&&c| c == 512 << 20).count(), 1);
        assert!(hot.iter().filter(|&&c| c < 4 << 20).count() >= p - 1);
        for _ in 0..32 {
            let v = counts::irregular(&mut rng, p, 1 << 24);
            assert_eq!(v.len(), p);
        }
    }

    #[test]
    fn reduce_widths_never_all_zero() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(7);
        for _ in 0..256 {
            let v = counts::reduce_widths(&mut rng, 8, 1 << 20);
            assert_eq!(v.len(), 8);
            assert!(v.iter().any(|&c| c > 0), "all-zero reduce vector");
        }
    }

    #[test]
    fn alltoallv_matrix_is_square_with_zero_diagonal() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(11);
        for p in [1usize, 2, 5, 8, 16] {
            let m = counts::alltoallv_matrix(&mut rng, p, 1 << 20);
            assert_eq!(m.len(), p * p);
            for r in 0..p {
                assert_eq!(m[r * p + r], 0, "diagonal {r} not resident");
            }
        }
    }

    #[test]
    fn fabric_generators_stay_in_their_ranges() {
        use crate::topology::systems::SystemSpec;
        use crate::util::prng::Rng;
        let mut rng = Rng::new(13);
        for _ in 0..128 {
            match fabrics::any_fabric(&mut rng) {
                SystemSpec::FatTree { k } => {
                    assert!(k % 2 == 0 && (2..=8).contains(&k), "k={k}")
                }
                SystemSpec::Dragonfly { a, p, h } => {
                    assert!((1..=4).contains(&a) && (1..=3).contains(&p) && (1..=3).contains(&h))
                }
                SystemSpec::MultiPlanePod { nodes, gpus, rails } => {
                    assert!((1..=6).contains(&nodes) && (1..=8).contains(&gpus));
                    assert!((1..=gpus).contains(&rails));
                }
                SystemSpec::Paper(_) => panic!("fabric generator yielded a paper system"),
            }
        }
    }

    #[test]
    fn macro_returns_err() {
        fn prop(v: u64) -> Result<(), String> {
            prop_assert!(v < 10, "v too big: {v}");
            Ok(())
        }
        assert!(prop(5).is_ok());
        assert!(prop(50).is_err());
    }
}
