//! Bounded worker pool for embarrassingly parallel job batches.
//!
//! The first generation of `report::parallel_map` spawned **one OS
//! thread per job** — fine for the 8-cell fig2 grid, pathological for
//! sweeps with hundreds of cells (thread churn, stack memory, scheduler
//! pressure). This pool spawns at most
//! [`std::thread::available_parallelism`] scoped workers and feeds them
//! jobs through an atomic cursor; results come back in job order.
//!
//! Scoped threads (stable since 1.63) mean jobs may borrow from the
//! caller's stack — the gdr-limit sweep hands workers `&Topology` /
//! `&TensorSpec` directly instead of cloning into `'static` closures.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers a batch of `jobs` jobs will use: the machine's
/// available parallelism (fallback 4 if undetectable), capped by the job
/// count.
pub fn worker_count(jobs: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4);
    // not .clamp(): jobs may be 0, and clamp(1, 0) would panic
    if jobs == 0 {
        1
    } else {
        hw.min(jobs)
    }
}

/// Run every job on a bounded pool of scoped worker threads and collect
/// the results in job order.
///
/// Jobs are claimed through an atomic cursor, so a long job does not
/// hold up the queue behind it. A panicking job propagates: the scope
/// join panics the caller, matching the old spawn-per-job behavior.
pub fn parallel_map<T, F>(jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    parallel_map_n(usize::MAX, jobs)
}

/// [`parallel_map`] with an explicit worker ceiling: at most
/// `max_workers` scoped threads (still capped by available parallelism
/// and the job count). The sharded event engine uses this to fan shard
/// runs across a *chosen* number of workers — its speedup curve in
/// `BENCH_engine.json` sweeps this knob — and `max_workers = 1` is the
/// deterministic inline path.
pub fn parallel_map_n<T, F>(max_workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    // zero/one job or a single-core box: run inline, no threads
    let workers = worker_count(n).min(max_workers.max(1));
    if n <= 1 || workers == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let next = AtomicUsize::new(0);
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i]
                    .lock()
                    .expect("job slot poisoned")
                    .take()
                    .expect("job claimed twice");
                let out = job();
                *results[i].lock().expect("result slot poisoned") = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("worker panicked")
                .expect("job skipped")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..64usize).map(|i| move || i * i).collect();
        let out = parallel_map(jobs);
        assert_eq!(out, (0..64usize).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn handles_many_more_jobs_than_cores() {
        // the old spawn-per-job implementation created 1000 OS threads
        // here; the pool must stay bounded and still finish correctly
        let jobs: Vec<_> = (0..1000usize).map(|i| move || i + 1).collect();
        let out = parallel_map(jobs);
        assert_eq!(out.len(), 1000);
        assert_eq!(out[999], 1000);
        assert_eq!(out.iter().sum::<usize>(), 1000 * 1001 / 2);
    }

    #[test]
    fn jobs_may_borrow_from_the_caller() {
        // scoped workers: no 'static bound on the closures
        let data: Vec<u64> = (0..100).collect();
        let jobs: Vec<_> = (0..10usize)
            .map(|c| {
                let data = &data;
                move || data.iter().skip(c * 10).take(10).sum::<u64>()
            })
            .collect();
        let out = parallel_map(jobs);
        assert_eq!(out.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<fn() -> u8> = Vec::new();
        assert!(parallel_map(empty).is_empty());
        assert_eq!(parallel_map(vec![|| 7u8]), vec![7]);
    }

    #[test]
    fn bounded_worker_override() {
        // max_workers = 1 runs inline and in order; a mid-size ceiling
        // still returns results in job order
        for cap in [1usize, 2, 3] {
            let jobs: Vec<_> = (0..25usize).map(|i| move || i * 2).collect();
            let out = parallel_map_n(cap, jobs);
            assert_eq!(out, (0..25usize).map(|i| i * 2).collect::<Vec<_>>());
        }
        // max_workers = 0 is treated as 1, not a panic
        assert_eq!(parallel_map_n(0, vec![|| 5u8]), vec![5]);
    }

    #[test]
    fn worker_count_is_bounded() {
        assert_eq!(worker_count(0), 1);
        assert_eq!(worker_count(1), 1);
        let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        assert_eq!(worker_count(10_000), hw);
    }
}
