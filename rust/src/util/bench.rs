//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Every `cargo bench` target (`harness = false`) uses this: warmup,
//! fixed-count timed iterations, and a stable one-line report with
//! mean / p50 / p95 / min. Results are also returned so bench binaries
//! can dump CSV next to the figure data.

use std::time::Instant;

use super::stats::percentile;

/// Timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name as printed in the report.
    pub name: String,
    /// Timed iterations (excluding warmup).
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub p50_s: f64,
    /// 95th-percentile seconds per iteration.
    pub p95_s: f64,
    /// Fastest iteration in seconds.
    pub min_s: f64,
}

impl BenchResult {
    /// Stable one-line report (name, iters, mean/p50/p95/min).
    pub fn report_line(&self) -> String {
        format!(
            "{:<48} {:>6} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            super::fmt_time(self.mean_s),
            super::fmt_time(self.p50_s),
            super::fmt_time(self.p95_s),
            super::fmt_time(self.min_s),
        )
    }
}

/// Run `f` with `warmup` discarded iterations then `iters` timed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean_s = samples.iter().sum::<f64>() / iters as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s,
        p50_s: percentile(&samples, 50.0),
        p95_s: percentile(&samples, 95.0),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Opaque value sink preventing the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut count = 0usize;
        let r = bench("t", 2, 5, || count += 1);
        assert_eq!(count, 7); // warmup + timed
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s * 1.0001);
    }

    #[test]
    fn bench_orders_percentiles() {
        let r = bench("t", 0, 20, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.min_s <= r.p50_s);
        assert!(r.p50_s <= r.p95_s * 1.0001);
    }

    #[test]
    fn report_line_contains_name() {
        let r = bench("my_bench", 0, 1, || {});
        assert!(r.report_line().contains("my_bench"));
    }
}
