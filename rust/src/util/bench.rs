//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Every `cargo bench` target (`harness = false`) uses this: warmup,
//! fixed-count timed iterations, and a stable one-line report with
//! mean / p50 / p95 / min. Results are also returned so bench binaries
//! can dump CSV next to the figure data.

use std::time::Instant;

use super::json::{obj, Json};
use super::stats::percentile;

/// Is quick (smoke) mode on? Set `AGV_BENCH_QUICK=1` to slash iteration
/// counts across every bench target — the CI bench-smoke step uses this
/// so the bench binaries keep building and running without burning
/// minutes on real measurement.
pub fn quick_mode() -> bool {
    std::env::var("AGV_BENCH_QUICK").map(|v| v == "1" || v == "true").unwrap_or(false)
}

/// `n` normally, 1 in quick mode. Wrap every bench target's timed
/// iteration count in this.
pub fn iters(n: usize) -> usize {
    if quick_mode() {
        1
    } else {
        n
    }
}

/// `n` normally, 0 in quick mode. Wrap warmup counts in this.
pub fn warmup(n: usize) -> usize {
    if quick_mode() {
        0
    } else {
        n
    }
}

/// Timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name as printed in the report.
    pub name: String,
    /// Timed iterations (excluding warmup).
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub p50_s: f64,
    /// 95th-percentile seconds per iteration.
    pub p95_s: f64,
    /// Fastest iteration in seconds.
    pub min_s: f64,
}

impl BenchResult {
    /// Machine-readable form for `BENCH_*.json` files. `extra` appends
    /// derived metrics (e.g. `flows_per_s`) next to the timing fields.
    pub fn to_json(&self, extra: &[(&str, f64)]) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_s", Json::Num(self.mean_s)),
            ("p50_s", Json::Num(self.p50_s)),
            ("p95_s", Json::Num(self.p95_s)),
            ("min_s", Json::Num(self.min_s)),
        ];
        for &(k, v) in extra {
            pairs.push((k, Json::Num(v)));
        }
        obj(pairs)
    }

    /// Stable one-line report (name, iters, mean/p50/p95/min).
    pub fn report_line(&self) -> String {
        format!(
            "{:<48} {:>6} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            super::fmt_time(self.mean_s),
            super::fmt_time(self.p50_s),
            super::fmt_time(self.p95_s),
            super::fmt_time(self.min_s),
        )
    }
}

/// Run `f` with `warmup` discarded iterations then `iters` timed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean_s = samples.iter().sum::<f64>() / iters as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s,
        p50_s: percentile(&samples, 50.0),
        p95_s: percentile(&samples, 95.0),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Opaque value sink preventing the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut count = 0usize;
        let r = bench("t", 2, 5, || count += 1);
        assert_eq!(count, 7); // warmup + timed
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s * 1.0001);
    }

    #[test]
    fn bench_orders_percentiles() {
        let r = bench("t", 0, 20, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.min_s <= r.p50_s);
        assert!(r.p50_s <= r.p95_s * 1.0001);
    }

    #[test]
    fn report_line_contains_name() {
        let r = bench("my_bench", 0, 1, || {});
        assert!(r.report_line().contains("my_bench"));
    }

    #[test]
    fn to_json_has_timing_and_extra_fields() {
        let r = bench("j", 0, 3, || {});
        let j = r.to_json(&[("flows_per_s", 123.5)]);
        assert_eq!(j.get("name").unwrap().as_str(), Some("j"));
        assert_eq!(j.get("iters").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("flows_per_s").unwrap().as_f64(), Some(123.5));
        assert!(j.get("mean_s").unwrap().as_f64().unwrap() >= 0.0);
        // must render to parseable JSON for the BENCH_*.json artifacts
        let rendered = j.render();
        assert_eq!(crate::util::json::Json::parse(&rendered).unwrap(), j);
    }

    // quick_mode()/iters()/warmup() read the environment; mutating env
    // vars in parallel unit tests races, so their contract is exercised
    // by the CI bench-smoke step (AGV_BENCH_QUICK=1 make bench-smoke).
}
