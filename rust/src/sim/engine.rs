//! The event engine: task DAG execution with max-min fair flow rates.
//!
//! This is the **event-driven** core (DESIGN.md §8). The previous
//! generation of the engine — kept verbatim in [`super::reference`] as a
//! differential-testing oracle — scanned every active flow at every
//! event to find the next completion, advanced byte accounting for every
//! flow at every event, and rebuilt max-min rates from scratch on every
//! start/finish: O(F²·L) for F concurrent flows. This engine replaces
//! all three hot paths:
//!
//! 1. **Prediction heap** — predicted flow completions live in a lazy
//!    min-heap keyed by `(now + remaining/rate, seq)`. Every entry is
//!    stamped with the flow's *epoch* (bumped on every rate change);
//!    stale entries are discarded on pop instead of being searched for
//!    and removed. Finding the next completion is O(log F).
//! 2. **Lazy settlement** — rates are piecewise constant between rate
//!    changes, so each flow records `last_update` and settles its
//!    `remaining`/`linkdir_bytes` only when its rate changes, when it
//!    completes, or never again (run end implies completion). Events
//!    that do not touch a flow cost it nothing.
//! 3. **Incremental max-min** — per-linkdir membership lists let the
//!    progressive-filling refill visit only linkdirs that are actually
//!    loaded, and two *fast paths* skip the refill entirely: a flow
//!    finishing whose linkdirs are all unsaturated (or left empty by its
//!    departure) cannot raise anyone else's rate, and a flow starting on
//!    linkdirs it occupies alone takes the spare capacity without
//!    disturbing anyone. Serialized chains — the common shape of
//!    staged/pipelined transports — never trigger a full refill.
//!
//! Link capacities are **piecewise-constant in time** (DESIGN.md §12):
//! [`Sim::capacity_event`] schedules steps that rewrite a link's
//! per-direction capacity at an instant. A step on a *loaded* linkdir
//! triggers the same incremental refill a flow start/finish does (lazy
//! settlement at the change keeps byte conservation exact across
//! steps); a step on an idle linkdir just updates `caps`/`spare` and
//! costs zero refills. Steps that would not change the capacity
//! bit-for-bit are filtered out before the run ([`capacity_timeline`]),
//! which is what makes zero-magnitude perturbations bit-exact no-ops.

use std::cell::Cell;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::topology::{LinkId, Path, Topology};

/// Handle to a task in the simulation DAG.
pub type TaskId = usize;

/// A (link, direction) capacity domain. Direction 0 = a->b, 1 = b->a.
pub(crate) type LinkDir = usize;

#[derive(Clone, Debug)]
pub(crate) enum TaskSpec {
    /// Bytes moving along `linkdirs`; `latency` elapses between readiness
    /// and the first byte (wire latency + protocol overhead).
    Flow {
        linkdirs: Vec<LinkDir>,
        bytes: f64,
        latency: f64,
    },
    /// Pure virtual-time delay (API call overhead, kernel launch, ...).
    Delay { secs: f64 },
}

#[derive(Clone, Debug)]
pub(crate) struct Task {
    pub(crate) spec: TaskSpec,
    /// Number of incomplete dependencies.
    pub(crate) pending_deps: usize,
    /// Tasks to notify on completion.
    pub(crate) dependents: Vec<TaskId>,
    /// Completion time, once known.
    pub(crate) finish: Option<f64>,
}

/// Scheduled discrete event.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Event {
    /// A flow's latency elapsed: its bytes start moving.
    Activate(TaskId),
    /// A delay task finished.
    DelayDone(TaskId),
}

/// Min-heap entry ordered by (time, seq) for determinism.
#[derive(Clone, Copy, Debug)]
pub(crate) struct HeapEntry {
    pub(crate) time: f64,
    pub(crate) seq: u64,
    pub(crate) event: Event,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Predicted completion of an active flow. Stale entries (the flow's
/// rate changed since the prediction, bumping its epoch, or the slot was
/// recycled) are discarded lazily on pop.
#[derive(Clone, Copy, Debug)]
struct Prediction {
    time: f64,
    seq: u64,
    slot: u32,
    epoch: u64,
}

impl PartialEq for Prediction {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Prediction {}
impl PartialOrd for Prediction {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Prediction {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: earliest prediction first, push order breaks ties
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// An active flow slot. Slots live in a slab (`free` list recycles them)
/// so per-linkdir membership lists can hold stable `u32` indices.
#[derive(Clone, Debug)]
struct FlowSlot {
    task: TaskId,
    /// Bytes left as of `last_update` (settled lazily).
    remaining: f64,
    rate: f64,
    /// Virtual time up to which `remaining`/`linkdir_bytes` are settled.
    last_update: f64,
    /// Bumped on every rate change; invalidates heap predictions.
    epoch: u64,
    alive: bool,
    /// Position in `active_list` for O(1) swap-removal.
    list_pos: u32,
    linkdirs: Vec<LinkDir>,
    /// `member_pos[k]` = this flow's position in
    /// `members[linkdirs[k]]`, for O(1) membership swap-removal
    /// (a linear scan here would reintroduce O(F²) work on
    /// shared-link completion batches).
    member_pos: Vec<u32>,
}

/// Engine instrumentation counters, reported on [`SimResult::stats`].
///
/// These exist so scaling regressions are testable by *counting work*
/// instead of timing it: `tests/engine_scaling.rs` asserts linear bounds
/// on them for workloads the old quadratic core handled in O(F²).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SimStats {
    /// Discrete events fired (activations + delay completions).
    pub events: u64,
    /// Flow completions delivered from the prediction heap.
    pub completions: u64,
    /// Full progressive-filling rate recomputations.
    pub full_refills: u64,
    /// Flow visits summed over all refill rounds — the engine's actual
    /// rate-recompute work, which is where quadratic behavior would
    /// resurface (the scaling regression test bounds this).
    pub refill_flow_visits: u64,
    /// Flow starts/finishes absorbed by the incremental fast paths.
    pub fast_updates: u64,
    /// Lazy byte settlements that actually moved bytes.
    pub settlements: u64,
    /// Completion predictions pushed onto the heap.
    pub heap_pushes: u64,
    /// Capacity-change events applied (no-op changes are filtered out
    /// before the run and never reach this counter — nor the engine).
    pub cap_events: u64,
    /// Connected-component shards that actually executed when the
    /// sharded driver ([`super::sharded`]) ran this simulation: 0 for
    /// plain single-engine runs, 1 when union-find collapsed every task
    /// into one component and the driver short-circuited to the plain
    /// engine, `n` when `n` shards genuinely ran in parallel.
    pub shards_effective: u64,
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub(crate) finish: Vec<f64>,
    /// Virtual time when the last task completed.
    pub makespan: f64,
    /// Total bytes carried per (link, direction) — for utilization
    /// reports and conservation checks in tests.
    pub linkdir_bytes: Vec<f64>,
    /// Number of flows simulated.
    pub flows: usize,
    /// Engine work counters (all-zero when the reference engine ran).
    pub stats: SimStats,
}

/// Terminal outcome of a simulation run (DESIGN.md §14).
///
/// Every run terminates with one of these — the engines never hang and
/// never emit non-finite times. A run stalls when active flows have zero
/// aggregate capacity (their paths cross links whose capacity stepped to
/// zero — an outage, [`crate::perturb::Perturbation::LinkDown`] /
/// [`crate::perturb::Perturbation::GpuDown`]) and no pending capacity
/// step can revive them, or when tasks wait on dependencies that can
/// never complete.
#[derive(Clone, Debug, PartialEq)]
pub enum SimOutcome {
    /// Every task finished; `time` is the makespan.
    Completed {
        /// Virtual time of the last task completion.
        time: f64,
    },
    /// Progress stopped before all tasks finished. All fields are the
    /// stall *diagnosis*: which tasks are stuck, how many in-flight
    /// flows are starved at rate zero, and which zero-capacity links
    /// starve them (empty when the stall is a dependency cycle rather
    /// than an outage).
    Stalled {
        /// Virtual time at which progress stopped (finite).
        time: f64,
        /// Tasks that never completed, **sorted ascending and deduped**
        /// — consumers binary-search this (`workload::slo` classifies a
        /// job as completed iff its `done` task is absent). Every
        /// engine builds the variant through [`SimOutcome::stalled`],
        /// which enforces the ordering contract.
        stuck_tasks: Vec<TaskId>,
        /// Active flows frozen at rate zero with bytes remaining.
        starved_flows: usize,
        /// Zero-capacity links crossed by starved flows (sorted, deduped).
        culprit_links: Vec<LinkId>,
    },
}

impl SimOutcome {
    /// Build a stall diagnosis, normalizing the container contracts:
    /// `stuck_tasks` comes out sorted ascending and deduped (callers
    /// binary-search it — an unsorted diagnosis would silently
    /// misclassify stuck ops as completed and inflate goodput), and
    /// `culprit_links` comes out sorted and deduped. All three engines
    /// (event-driven, reference, sharded merge) construct `Stalled`
    /// exclusively through here so the contract cannot drift per
    /// construction site.
    pub fn stalled(
        time: f64,
        mut stuck_tasks: Vec<TaskId>,
        starved_flows: usize,
        mut culprit_links: Vec<LinkId>,
    ) -> SimOutcome {
        stuck_tasks.sort_unstable();
        stuck_tasks.dedup();
        culprit_links.sort_unstable();
        culprit_links.dedup();
        SimOutcome::Stalled { time, stuck_tasks, starved_flows, culprit_links }
    }
    /// Did every task complete?
    pub fn is_completed(&self) -> bool {
        matches!(self, SimOutcome::Completed { .. })
    }

    /// Terminal virtual time: the makespan, or the instant progress
    /// stopped. Always finite.
    pub fn time(&self) -> f64 {
        match self {
            SimOutcome::Completed { time } | SimOutcome::Stalled { time, .. } => *time,
        }
    }

    /// Zero-capacity links named by a stall diagnosis (empty for
    /// completed runs and dependency-cycle stalls).
    pub fn culprit_links(&self) -> &[LinkId] {
        match self {
            SimOutcome::Completed { .. } => &[],
            SimOutcome::Stalled { culprit_links, .. } => culprit_links,
        }
    }

    /// One-line human description of the outcome, used by the
    /// [`Sim::run`] panic path and the fault reports.
    pub fn describe(&self) -> String {
        match self {
            SimOutcome::Completed { time } => format!("completed at {time:.6}s"),
            SimOutcome::Stalled { time, stuck_tasks, starved_flows, culprit_links } => {
                if culprit_links.is_empty() {
                    format!(
                        "stalled at {time:.6}s: {} stuck tasks, no runnable events \
                         (cyclic or unsatisfiable dependencies?)",
                        stuck_tasks.len()
                    )
                } else {
                    format!(
                        "stalled at {time:.6}s: {} stuck tasks, {starved_flows} starved \
                         flows on dead links {culprit_links:?}",
                        stuck_tasks.len()
                    )
                }
            }
        }
    }
}

impl SimResult {
    /// Completion time of a task (virtual seconds).
    pub fn finish(&self, id: TaskId) -> f64 {
        self.finish[id]
    }

    /// Completion times of every task, in task order.
    pub fn finish_times(&self) -> &[f64] {
        &self.finish
    }

    /// Total bytes over a link, both directions.
    pub fn link_bytes(&self, link: LinkId) -> f64 {
        self.linkdir_bytes[2 * link] + self.linkdir_bytes[2 * link + 1]
    }
}

thread_local! {
    /// When set, [`Sim::run`] dispatches to the reference engine. Tests
    /// use this (via [`with_reference_engine`]) to route entire comm
    /// models through the pre-rewrite core for differential comparison.
    static FORCE_REFERENCE: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with every [`Sim::run`] on this thread dispatched to the
/// reference (pre-rewrite) engine — the seam differential tests and the
/// engine A/B bench use to drive unmodified comm models through both
/// cores. Thread-local, so parallel tests do not interfere; note that
/// worker threads spawned inside `f` (e.g. `util::pool`) do *not*
/// inherit the override.
pub fn with_reference_engine<T>(f: impl FnOnce() -> T) -> T {
    struct Reset(bool);
    impl Drop for Reset {
        fn drop(&mut self) {
            FORCE_REFERENCE.with(|c| c.set(self.0));
        }
    }
    let _reset = Reset(FORCE_REFERENCE.with(|c| c.replace(true)));
    f()
}

/// Is the thread-local reference-engine override active on this
/// thread? [`super::replay::Baseline`] checks this when recording: under
/// the override a baseline degrades to cold re-runs so differential
/// tests still route every simulation through the reference core.
pub(crate) fn reference_forced() -> bool {
    FORCE_REFERENCE.with(|c| c.get())
}

/// Compact event log recorded by a baseline run (DESIGN.md §16).
///
/// Only **rate assignments** are recorded — one `(time, rate)` pair per
/// task at each of the engine's two rate-assignment sites (full-refill
/// apply and the sole-occupant start fast path). Everything else the
/// warm-start seam needs is already implied: task finishes live on
/// [`SimResult::finish`], activation instants are dependency finishes
/// plus latency, and a flow's rate is 0.0 from activation until its
/// first record. [`super::replay`] reconstructs the engine's full
/// settled state at any instant from this plus the baseline result.
#[derive(Clone, Debug, Default)]
pub(crate) struct EventLog {
    /// `rates[task]` = chronological `(time, rate)` assignments for the
    /// flow owned by `task` (empty for delays and zero-byte flows).
    pub(crate) rates: Vec<Vec<(f64, f64)>>,
}

impl EventLog {
    pub(crate) fn new(tasks: usize) -> EventLog {
        EventLog { rates: vec![Vec::new(); tasks] }
    }
}

/// An in-flight flow reconstructed at the warm-start instant.
#[derive(Clone, Debug)]
pub(crate) struct WarmFlow {
    pub(crate) task: TaskId,
    /// Bytes left at the resume instant.
    pub(crate) remaining: f64,
    /// Rate under the baseline's settled allocation at the resume
    /// instant (a live capacity step re-shares it only if it lands on
    /// a loaded linkdir).
    pub(crate) rate: f64,
    pub(crate) linkdirs: Vec<LinkDir>,
}

/// Pre-settled engine state at a resume instant, built by
/// [`super::replay::Baseline`] from a baseline's event log. The engine
/// seeds its loop state from this instead of t=0 and simulates live
/// only from `now` onward.
#[derive(Clone, Debug)]
pub(crate) struct WarmStart {
    /// Resume instant — the first divergence point.
    pub(crate) now: f64,
    /// Tasks already finished by `now` with their baseline finish
    /// times, in task order.
    pub(crate) finished: Vec<(TaskId, f64)>,
    /// Flows activated by `now` (matches [`SimResult::flows`] rules:
    /// positive-byte flow tasks whose activation instant has passed).
    pub(crate) flows_total: usize,
    /// Bytes already delivered per linkdir by `now`.
    pub(crate) linkdir_bytes: Vec<f64>,
    /// Flows in flight at `now`.
    pub(crate) flows: Vec<WarmFlow>,
    /// Discrete events scheduled but not yet fired at `now` (ready
    /// tasks waiting out latency/delay), sorted by (time, task).
    pub(crate) events: Vec<(f64, Event)>,
}

/// A scheduled capacity step: at `time`, both directions of `link`
/// switch to `capacity` bytes/s (piecewise-constant between steps).
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) struct CapEvent {
    pub(crate) time: f64,
    pub(crate) link: LinkId,
    pub(crate) capacity: f64,
}

/// Resolve raw capacity events into a per-*linkdir* timeline, sorted by
/// time (insertion order breaks ties — later events override earlier
/// ones at the same instant) with **no-op steps filtered out**: a step
/// whose capacity is bit-identical to the linkdir's value at that point
/// never reaches either engine. This is what makes an empty or
/// zero-magnitude perturbation set *bit-exact* to the unperturbed
/// simulation on both cores (`tests/faults_differential.rs`): no extra
/// event instants, no extra settlements, no reordered arithmetic.
pub(crate) fn capacity_timeline(
    topo: &Topology,
    cap_events: &[CapEvent],
) -> Vec<(f64, LinkDir, f64)> {
    if cap_events.is_empty() {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..cap_events.len()).collect();
    // stable: same-time events keep insertion order
    order.sort_by(|&a, &b| cap_events[a].time.total_cmp(&cap_events[b].time));
    let mut cur: Vec<f64> = (0..topo.links.len() * 2)
        .map(|ld| topo.links[ld / 2].class.bandwidth())
        .collect();
    let mut out = Vec::new();
    for i in order {
        let e = &cap_events[i];
        for ld in [2 * e.link, 2 * e.link + 1] {
            if e.capacity.to_bits() != cur[ld].to_bits() {
                cur[ld] = e.capacity;
                out.push((e.time, ld, e.capacity));
            }
        }
    }
    out
}

/// Simulator for one collective (or one batched schedule of them).
pub struct Sim<'t> {
    pub(crate) topo: &'t Topology,
    pub(crate) tasks: Vec<Task>,
    pub(crate) roots: Vec<TaskId>,
    pub(crate) cap_events: Vec<CapEvent>,
}

impl<'t> Sim<'t> {
    /// Start building a simulation over a topology.
    pub fn new(topo: &'t Topology) -> Sim<'t> {
        Sim { topo, tasks: Vec::new(), roots: Vec::new(), cap_events: Vec::new() }
    }

    /// The topology this simulation runs over. The returned reference
    /// carries the topology's own lifetime (not the borrow of `self`),
    /// so composition helpers can hold it across `&mut Sim` calls.
    pub fn topology(&self) -> &'t Topology {
        self.topo
    }

    /// Number of tasks defined so far — a *mark* for range accounting.
    /// `comm` composition entry points snapshot this before building an
    /// op's subgraph so the workload engine can attribute flows per op.
    pub fn task_count(&self) -> usize {
        self.tasks.len()
    }

    /// Number of flow tasks with a positive byte count defined at or
    /// after `mark` (a value previously returned by [`Sim::task_count`]).
    /// Matches [`SimResult::flows`] accounting: zero-byte flows complete
    /// instantly and are not counted as simulated flows by either engine.
    pub fn flow_tasks_since(&self, mark: usize) -> usize {
        self.tasks[mark..]
            .iter()
            .filter(|t| matches!(t.spec, TaskSpec::Flow { bytes, .. } if bytes > 0.0))
            .count()
    }

    fn push(&mut self, spec: TaskSpec, deps: &[TaskId]) -> TaskId {
        let id = self.tasks.len();
        for &d in deps {
            assert!(d < id, "dependency {d} of task {id} not yet defined");
            self.tasks[d].dependents.push(id);
        }
        self.tasks.push(Task {
            spec,
            pending_deps: deps.len(),
            dependents: Vec::new(),
            finish: None,
        });
        if deps.is_empty() {
            self.roots.push(id);
        }
        id
    }

    /// Add a flow of `bytes` along `path`, starting `latency` seconds
    /// after all `deps` complete.
    pub fn flow(&mut self, path: Path, bytes: f64, latency: f64, deps: &[TaskId]) -> TaskId {
        assert!(bytes >= 0.0 && latency >= 0.0);
        let linkdirs = path
            .links
            .iter()
            .zip(path.devices.windows(2))
            .map(|(&l, w)| {
                let link = &self.topo.links[l];
                if link.a == w[0] && link.b == w[1] {
                    2 * l
                } else {
                    debug_assert!(link.b == w[0] && link.a == w[1]);
                    2 * l + 1
                }
            })
            .collect();
        self.push(TaskSpec::Flow { linkdirs, bytes, latency }, deps)
    }

    /// Add a pure delay task.
    pub fn delay(&mut self, secs: f64, deps: &[TaskId]) -> TaskId {
        assert!(secs >= 0.0);
        self.push(TaskSpec::Delay { secs }, deps)
    }

    /// Schedule a **capacity step**: from virtual time `time` onward,
    /// both directions of `link` run at `capacity` bytes/s instead of
    /// the link class's base bandwidth (piecewise-constant between
    /// steps; a later step on the same link overrides). Flows in flight
    /// re-share the new capacity at the step instant via the incremental
    /// max-min refill; lazy byte settlement at the rate change keeps
    /// conservation exact across steps. A step whose capacity equals the
    /// link's value at that instant bit-for-bit is filtered out before
    /// the run and perturbs nothing — the zero-perturbation differential
    /// contract ([`crate::perturb`]).
    pub fn capacity_event(&mut self, link: LinkId, time: f64, capacity: f64) {
        assert!(link < self.topo.links.len(), "capacity_event: no link {link}");
        assert!(
            time.is_finite() && time >= 0.0,
            "capacity_event: time must be finite and non-negative, got {time}"
        );
        assert!(
            capacity.is_finite() && capacity >= 0.0,
            "capacity_event: capacity must be finite and non-negative, got {capacity}"
        );
        self.cap_events.push(CapEvent { time, link, capacity });
    }

    /// A zero-cost join point over several dependencies (barrier).
    pub fn join(&mut self, deps: &[TaskId]) -> TaskId {
        self.push(TaskSpec::Delay { secs: 0.0 }, deps)
    }

    /// Execute the DAG; consumes the builder. Panics with the full
    /// stall diagnosis if the run cannot complete (zero-capacity outage
    /// with no revival, or a dependency cycle) — callers that inject
    /// outages use [`Sim::run_outcome`] instead.
    ///
    /// Dispatches to [`Sim::run_reference`] inside
    /// [`with_reference_engine`] scopes; otherwise runs the event-driven
    /// engine below.
    pub fn run(self) -> SimResult {
        let (res, outcome) = self.run_outcome();
        if !outcome.is_completed() {
            panic!("simulation deadlock: {}", outcome.describe());
        }
        res
    }

    /// Execute the DAG and report the terminal [`SimOutcome`] instead of
    /// panicking on a stall. On a stall the [`SimResult`] is still fully
    /// populated and finite: finished tasks keep their exact times,
    /// stuck tasks report the stall instant, and `linkdir_bytes` holds
    /// exactly what was delivered before progress stopped.
    ///
    /// On a completed run both the result *and the work counters* are
    /// bit-identical to [`Sim::run`] — the liveness check adds no event
    /// instants and no arithmetic.
    pub fn run_outcome(self) -> (SimResult, SimOutcome) {
        if FORCE_REFERENCE.with(|c| c.get()) {
            return self.run_reference_outcome();
        }
        self.run_event_driven()
    }

    /// The event-driven core, unconditionally (no reference-engine
    /// dispatch). The sharded driver ([`super::sharded`]) calls this
    /// directly from pool workers because the [`with_reference_engine`]
    /// override is thread-local and deliberately does not propagate to
    /// spawned threads — a shard must never silently switch cores.
    pub(crate) fn run_event_driven(self) -> (SimResult, SimOutcome) {
        self.run_core(None, None)
    }

    /// Event-driven run that also records the compact [`EventLog`] a
    /// [`super::replay::Baseline`] replays from. Results and work
    /// counters are bit-identical to [`Sim::run_event_driven`] —
    /// recording only appends to the log at the two rate-assignment
    /// sites, adding no event instants and no arithmetic.
    pub(crate) fn run_event_driven_logged(self, log: &mut EventLog) -> (SimResult, SimOutcome) {
        self.run_core(Some(log), None)
    }

    /// Event-driven run resuming from a pre-settled [`WarmStart`]
    /// instead of t=0. The work counters count live work only — the
    /// replayed prefix costs nothing, which is the point of the
    /// delta-simulation tier (DESIGN.md §16).
    pub(crate) fn run_event_driven_warm(self, warm: WarmStart) -> (SimResult, SimOutcome) {
        self.run_core(None, Some(warm))
    }

    fn run_core(
        self,
        mut log: Option<&mut EventLog>,
        warm: Option<WarmStart>,
    ) -> (SimResult, SimOutcome) {
        let Sim { topo, mut tasks, roots, cap_events } = self;
        let n_linkdirs = topo.links.len() * 2;
        let mut caps: Vec<f64> = (0..n_linkdirs)
            .map(|ld| topo.links[ld / 2].class.bandwidth())
            .collect();
        // No-op-filtered capacity steps, consumed in time order.
        let cap_timeline = capacity_timeline(topo, &cap_events);
        let mut cap_idx = 0usize;
        let mut linkdir_bytes = vec![0.0; n_linkdirs];
        let mut stats = SimStats::default();

        // Discrete events (activations, delays), as in the reference.
        // Heap storage is reserved up front (capped) so thousand-rank
        // DAGs batch their pushes into one allocation instead of
        // doubling through reallocations mid-run; ordering and
        // arithmetic are unchanged.
        let heap_cap = tasks.len().min(1 << 20);
        let mut events: BinaryHeap<HeapEntry> = BinaryHeap::with_capacity(heap_cap);
        let mut seq = 0u64;

        // Lazy completion-prediction heap (§8 item 1).
        let mut predictions: BinaryHeap<Prediction> = BinaryHeap::with_capacity(heap_cap);
        let mut pred_seq = 0u64;

        // Flow slab + O(1)-removal active list + per-linkdir membership.
        let mut flows: Vec<FlowSlot> = Vec::new();
        let mut free: Vec<u32> = Vec::new();
        let mut active_list: Vec<u32> = Vec::new();
        // members[ld] holds (slot, k) with flows[slot].linkdirs[k] == ld
        // and flows[slot].member_pos[k] == position in members[ld]
        let mut members: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n_linkdirs];
        // Leftover capacity per linkdir under the current allocation.
        // Invariant: members[ld].is_empty() implies spare[ld] == caps[ld]
        // bitwise (restored exactly on last-member departure, so idle
        // links never accumulate floating-point drift).
        let mut spare: Vec<f64> = caps.clone();

        let mut now = 0.0f64;
        let mut flows_total = 0usize;
        let mut completed = 0usize;
        let total = tasks.len();
        // saturation threshold, as in the reference refill
        let eps = 1e-9;

        let mut ready_queue: Vec<(TaskId, f64)> = roots.iter().map(|&r| (r, 0.0)).collect();

        macro_rules! drain_ready {
            () => {
                while let Some((id, t)) = ready_queue.pop() {
                    let time = match tasks[id].spec {
                        TaskSpec::Flow { latency, .. } => t + latency,
                        TaskSpec::Delay { secs } => t + secs,
                    };
                    let event = match tasks[id].spec {
                        TaskSpec::Flow { .. } => Event::Activate(id),
                        TaskSpec::Delay { .. } => Event::DelayDone(id),
                    };
                    let s = seq;
                    seq += 1;
                    events.push(HeapEntry { time, seq: s, event });
                }
            };
        }

        macro_rules! finish_task {
            ($id:expr, $t:expr) => {{
                let id: TaskId = $id;
                tasks[id].finish = Some($t);
                completed += 1;
                for di in 0..tasks[id].dependents.len() {
                    let dep = tasks[id].dependents[di];
                    tasks[dep].pending_deps -= 1;
                    if tasks[dep].pending_deps == 0 {
                        ready_queue.push((dep, $t));
                    }
                }
            }};
        }

        // Settle a flow's lazy byte accounting up to `t` (§8 item 2).
        fn settle(f: &mut FlowSlot, linkdir_bytes: &mut [f64], t: f64, stats: &mut SimStats) {
            let dt = t - f.last_update;
            if dt > 0.0 && f.rate > 0.0 && f.remaining > 0.0 {
                let moved = (f.rate * dt).min(f.remaining);
                f.remaining -= moved;
                for &ld in &f.linkdirs {
                    linkdir_bytes[ld] += moved;
                }
                stats.settlements += 1;
            }
            f.last_update = t;
        }

        macro_rules! push_prediction {
            ($slot:expr) => {{
                let s: u32 = $slot;
                let f = &flows[s as usize];
                let time = if f.remaining <= 0.0 || f.rate.is_infinite() {
                    now
                } else if f.rate > 0.0 {
                    now + f.remaining / f.rate
                } else {
                    f64::INFINITY // stalled: revived by a later rate change
                };
                if time.is_finite() {
                    let ps = pred_seq;
                    pred_seq += 1;
                    predictions.push(Prediction { time, seq: ps, slot: s, epoch: f.epoch });
                    stats.heap_pushes += 1;
                }
            }};
        }

        // Scratch for the progressive-filling refill (hoisted, reused).
        let mut scratch_unfrozen: Vec<u32> = Vec::new();
        let mut scratch_loaded: Vec<LinkDir> = Vec::new();
        let mut scratch_touched: Vec<u64> = vec![0; n_linkdirs];
        let mut scratch_cnt: Vec<u32> = vec![0; n_linkdirs];
        let mut scratch_rate: Vec<f64> = Vec::new();
        let mut refill_id = 0u64;

        // Full max-min recompute via progressive filling (§8 item 3):
        // identical arithmetic to the reference, but the per-round scans
        // touch only loaded linkdirs (`scratch_loaded`) instead of every
        // linkdir in the topology, and new rates are *compared* to the
        // old ones so only flows whose rate actually changed pay a
        // settlement, an epoch bump and a heap push.
        macro_rules! full_refill {
            () => {{
                if !active_list.is_empty() {
                    stats.full_refills += 1;
                    refill_id += 1;
                    scratch_loaded.clear();
                    scratch_unfrozen.clear();
                    scratch_unfrozen.extend(active_list.iter().copied());
                    if scratch_rate.len() < flows.len() {
                        scratch_rate.resize(flows.len(), 0.0);
                    }
                    for &s in &scratch_unfrozen {
                        scratch_rate[s as usize] = 0.0;
                        for &ld in &flows[s as usize].linkdirs {
                            if scratch_touched[ld] != refill_id {
                                scratch_touched[ld] = refill_id;
                                scratch_loaded.push(ld);
                                spare[ld] = caps[ld];
                            }
                        }
                    }
                    while !scratch_unfrozen.is_empty() {
                        stats.refill_flow_visits += scratch_unfrozen.len() as u64;
                        for &ld in &scratch_loaded {
                            scratch_cnt[ld] = 0;
                        }
                        for &s in &scratch_unfrozen {
                            for &ld in &flows[s as usize].linkdirs {
                                scratch_cnt[ld] += 1;
                            }
                        }
                        // smallest fair increment across loaded linkdirs
                        let mut inc = f64::INFINITY;
                        for &ld in &scratch_loaded {
                            if scratch_cnt[ld] > 0 {
                                inc = inc.min(spare[ld] / scratch_cnt[ld] as f64);
                            }
                        }
                        if !inc.is_finite() {
                            for &s in &scratch_unfrozen {
                                scratch_rate[s as usize] = f64::INFINITY;
                            }
                            break;
                        }
                        for &s in &scratch_unfrozen {
                            scratch_rate[s as usize] += inc;
                        }
                        for &ld in &scratch_loaded {
                            spare[ld] -= inc * scratch_cnt[ld] as f64;
                        }
                        // freeze flows crossing saturated linkdirs
                        let before = scratch_unfrozen.len();
                        scratch_unfrozen.retain(|&s| {
                            let saturated = flows[s as usize]
                                .linkdirs
                                .iter()
                                .any(|&ld| spare[ld] <= eps * caps[ld]);
                            !saturated
                        });
                        if scratch_unfrozen.len() == before {
                            // Numerical safety: freeze all at current rates.
                            scratch_unfrozen.clear();
                        }
                    }
                    // apply: settle + re-predict only flows whose rate changed
                    for &s in &active_list {
                        let si = s as usize;
                        let r = scratch_rate[si];
                        if r.to_bits() != flows[si].rate.to_bits() {
                            settle(&mut flows[si], &mut linkdir_bytes, now, &mut stats);
                            flows[si].rate = r;
                            flows[si].epoch += 1;
                            if let Some(l) = log.as_deref_mut() {
                                l.rates[flows[si].task].push((now, r));
                            }
                            push_prediction!(s);
                        }
                    }
                }
            }};
        }

        if let Some(w) = warm {
            // Resume from a pre-settled instant (DESIGN.md §16): seed
            // the loop state the baseline had at `w.now` and simulate
            // live from there. No refill is forced here — the first
            // live capacity step triggers one only if it lands on a
            // loaded linkdir, exactly as in a cold run.
            debug_assert_eq!(w.linkdir_bytes.len(), n_linkdirs);
            now = w.now;
            linkdir_bytes = w.linkdir_bytes;
            flows_total = w.flows_total;
            // Roots already fired in the replayed prefix; pending work
            // is seeded explicitly below.
            ready_queue.clear();
            for &(id, t) in &w.finished {
                tasks[id].finish = Some(t);
                completed += 1;
                for di in 0..tasks[id].dependents.len() {
                    let dep = tasks[id].dependents[di];
                    tasks[dep].pending_deps -= 1;
                }
            }
            // Capacity steps strictly before the resume instant touch
            // only linkdirs no flow ever crosses (that is how the
            // divergence point is chosen); apply them directly so the
            // main loop never sees an event in the past.
            while let Some(&(t, ld, cap)) = cap_timeline.get(cap_idx) {
                if t >= now {
                    break;
                }
                cap_idx += 1;
                caps[ld] = cap;
                spare[ld] = cap;
                stats.cap_events += 1;
            }
            for &(t, e) in &w.events {
                let s = seq;
                seq += 1;
                events.push(HeapEntry { time: t, seq: s, event: e });
            }
            for wf in w.flows {
                // The slot owns the linkdirs for its active lifetime,
                // as on a live activation.
                if let TaskSpec::Flow { linkdirs, .. } = &mut tasks[wf.task].spec {
                    linkdirs.clear();
                }
                let slot = flows.len() as u32;
                flows.push(FlowSlot {
                    task: wf.task,
                    remaining: wf.remaining,
                    rate: wf.rate,
                    last_update: now,
                    epoch: 0,
                    alive: true,
                    list_pos: active_list.len() as u32,
                    linkdirs: wf.linkdirs,
                    member_pos: Vec::new(),
                });
                active_list.push(slot);
                let mut mp = Vec::with_capacity(flows[slot as usize].linkdirs.len());
                for (k, &ld) in flows[slot as usize].linkdirs.iter().enumerate() {
                    mp.push(members[ld].len() as u32);
                    members[ld].push((slot, k as u32));
                }
                flows[slot as usize].member_pos = mp;
            }
            // Loaded linkdirs carry the baseline allocation; idle ones
            // keep the exact-restore invariant (spare == caps bitwise).
            for ld in 0..n_linkdirs {
                if !members[ld].is_empty() {
                    let mut left = caps[ld];
                    for &(m, _) in &members[ld] {
                        left -= flows[m as usize].rate;
                    }
                    spare[ld] = left;
                }
            }
            for s in 0..flows.len() as u32 {
                push_prediction!(s);
            }
        }

        drain_ready!();

        let mut started: Vec<u32> = Vec::new();
        let mut stalled: Option<SimOutcome> = None;
        while completed < total {
            // Next valid predicted completion (discard stale entries).
            let mut next_completion = None;
            while let Some(p) = predictions.peek() {
                let f = &flows[p.slot as usize];
                if f.alive && f.epoch == p.epoch {
                    next_completion = Some(p.time);
                    break;
                }
                predictions.pop();
            }
            let next_event_t = events.peek().map(|e| e.time);
            let next_cap_t = cap_timeline.get(cap_idx).map(|e| e.0);
            let t_star = [next_event_t, next_completion, next_cap_t]
                .into_iter()
                .flatten()
                .fold(f64::INFINITY, f64::min);
            if !t_star.is_finite() {
                // Liveness (DESIGN.md §14): no discrete events, no finite
                // flow prediction, and no remaining capacity step that
                // could revive a starved flow — diagnose instead of
                // spinning. Every alive flow here sits at rate zero with
                // bytes remaining (a positive rate would have produced a
                // finite prediction), which under progressive filling
                // means its path crosses a zero-capacity linkdir.
                let mut starved_flows = 0usize;
                let mut culprit_links: Vec<LinkId> = Vec::new();
                for &s in &active_list {
                    let f = &flows[s as usize];
                    if f.alive && f.remaining > 0.0 {
                        starved_flows += 1;
                        culprit_links
                            .extend(f.linkdirs.iter().filter(|&&ld| caps[ld] <= 0.0).map(|&ld| ld / 2));
                    }
                }
                let stuck_tasks: Vec<TaskId> = tasks
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| t.finish.is_none())
                    .map(|(id, _)| id)
                    .collect();
                stalled = Some(SimOutcome::stalled(now, stuck_tasks, starved_flows, culprit_links));
                break;
            }
            assert!(
                t_star >= now - 1e-12,
                "time went backwards: {t_star} < {now}"
            );
            now = t_star;

            let mut needs_refill = false;
            let mut any_finished = false;
            started.clear();

            // Deliver every flow completion due now. The flow's entire
            // leftover is charged to its linkdirs (exact conservation:
            // the per-flow charges sum to precisely its byte count).
            while let Some(p) = predictions.peek() {
                if p.time > now {
                    break;
                }
                let p = *p;
                predictions.pop();
                let si = p.slot as usize;
                if !flows[si].alive || flows[si].epoch != p.epoch {
                    continue;
                }
                let moved = flows[si].remaining;
                if moved > 0.0 {
                    for &ld in &flows[si].linkdirs {
                        linkdir_bytes[ld] += moved;
                    }
                }
                flows[si].remaining = 0.0;
                flows[si].last_update = now;
                flows[si].alive = false;
                let task_id = flows[si].task;
                let rate = flows[si].rate;
                // O(1) active-list removal
                let pos = flows[si].list_pos as usize;
                active_list.swap_remove(pos);
                if pos < active_list.len() {
                    flows[active_list[pos] as usize].list_pos = pos as u32;
                }
                free.push(p.slot);
                // Membership + spare maintenance, and the finish fast
                // path decision (§8 item 3): a departure only forces a
                // refill if it leaves co-members behind on a saturated
                // linkdir — only they could now be entitled to rise.
                // Removal is O(1) per linkdir via member_pos (fix up the
                // swapped-in entry's back-pointer).
                let lds = std::mem::take(&mut flows[si].linkdirs);
                let mps = std::mem::take(&mut flows[si].member_pos);
                for (&ld, &mpos) in lds.iter().zip(&mps) {
                    let mpos = mpos as usize;
                    let list = &mut members[ld];
                    debug_assert_eq!(list[mpos].0, p.slot, "membership back-pointer corrupt");
                    list.swap_remove(mpos);
                    if mpos < list.len() {
                        let (s2, k2) = list[mpos];
                        flows[s2 as usize].member_pos[k2 as usize] = mpos as u32;
                    }
                    let list = &mut members[ld];
                    if list.is_empty() {
                        spare[ld] = caps[ld]; // idle again: exact restore
                    } else {
                        if spare[ld] <= eps * caps[ld] {
                            needs_refill = true;
                        }
                        spare[ld] += rate;
                    }
                }
                finish_task!(task_id, now);
                any_finished = true;
                stats.completions += 1;
            }

            // Apply capacity steps due now: the new capacity governs all
            // rates from this instant on (completions above were exact
            // under the old rates). An unloaded linkdir just takes the
            // new value — no refill, no settlement, nothing else moves
            // (the zero-refill guarantee `tests/engine_scaling.rs`
            // pins). A loaded linkdir forces a full refill, which
            // settles exactly the flows whose rate actually changes.
            let mut cap_changed = false;
            while let Some(&(t, ld, cap)) = cap_timeline.get(cap_idx) {
                if t > now {
                    break;
                }
                cap_idx += 1;
                caps[ld] = cap;
                stats.cap_events += 1;
                if members[ld].is_empty() {
                    spare[ld] = cap; // idle: exact restore, invariant kept
                } else {
                    cap_changed = true;
                }
            }

            // Fire discrete events at t_star.
            while let Some(e) = events.peek() {
                if e.time > now + 1e-18 {
                    break;
                }
                let e = events.pop().unwrap();
                stats.events += 1;
                match e.event {
                    Event::Activate(id) => {
                        let TaskSpec::Flow { bytes, .. } = tasks[id].spec else {
                            unreachable!()
                        };
                        if bytes <= 0.0 {
                            finish_task!(id, now);
                        } else {
                            // move the linkdirs out of the spec: the flow
                            // owns them for its active lifetime
                            let linkdirs = match &mut tasks[id].spec {
                                TaskSpec::Flow { linkdirs, .. } => std::mem::take(linkdirs),
                                TaskSpec::Delay { .. } => unreachable!(),
                            };
                            flows_total += 1;
                            if linkdirs.is_empty() {
                                // nothing to contend on: instant delivery
                                finish_task!(id, now);
                            } else {
                                let slot = if let Some(s) = free.pop() {
                                    let f = &mut flows[s as usize];
                                    f.task = id;
                                    f.remaining = bytes;
                                    f.rate = 0.0;
                                    f.last_update = now;
                                    f.epoch += 1; // invalidate recycled-slot leftovers
                                    f.alive = true;
                                    f.linkdirs = linkdirs;
                                    s
                                } else {
                                    flows.push(FlowSlot {
                                        task: id,
                                        remaining: bytes,
                                        rate: 0.0,
                                        last_update: now,
                                        epoch: 0,
                                        alive: true,
                                        list_pos: 0,
                                        linkdirs,
                                        member_pos: Vec::new(),
                                    });
                                    (flows.len() - 1) as u32
                                };
                                flows[slot as usize].list_pos = active_list.len() as u32;
                                active_list.push(slot);
                                let mut mp = Vec::with_capacity(
                                    flows[slot as usize].linkdirs.len(),
                                );
                                for (k, &ld) in
                                    flows[slot as usize].linkdirs.iter().enumerate()
                                {
                                    mp.push(members[ld].len() as u32);
                                    members[ld].push((slot, k as u32));
                                }
                                flows[slot as usize].member_pos = mp;
                                started.push(slot);
                            }
                        }
                    }
                    Event::DelayDone(id) => {
                        finish_task!(id, now);
                    }
                }
            }

            drain_ready!();

            // Rate maintenance (§8 item 3). The start fast path applies
            // only when every starter is the sole occupant of all its
            // linkdirs: it then takes the spare capacity (== full caps on
            // idle links) without disturbing any existing allocation. Any
            // sharing — including two simultaneous starters on one link —
            // falls back to the full refill, as does any departure that
            // left co-members on a saturated linkdir and any capacity
            // step that landed on a loaded linkdir.
            if !started.is_empty() || any_finished || cap_changed {
                let fast_start_ok = !needs_refill
                    && !cap_changed
                    && started.iter().all(|&s| {
                        flows[s as usize].linkdirs.iter().all(|&ld| members[ld].len() == 1)
                    });
                if fast_start_ok {
                    for &s in &started {
                        let si = s as usize;
                        let mut r = f64::INFINITY;
                        for &ld in &flows[si].linkdirs {
                            r = r.min(spare[ld]);
                        }
                        flows[si].rate = r;
                        if let Some(l) = log.as_deref_mut() {
                            l.rates[flows[si].task].push((now, r));
                        }
                        for &ld in &flows[si].linkdirs {
                            spare[ld] -= r;
                        }
                        push_prediction!(s);
                        stats.fast_updates += 1;
                    }
                    if any_finished {
                        stats.fast_updates += 1;
                    }
                } else {
                    full_refill!();
                }
            }
        }

        // Stuck tasks (stall path only) report the stall instant, so
        // every reported time stays finite; the completed path is
        // bit-identical to the pre-liveness engine (all tasks are Some).
        let finish: Vec<f64> = tasks.iter().map(|t| t.finish.unwrap_or(now)).collect();
        let makespan = finish.iter().cloned().fold(0.0, f64::max);
        let outcome = stalled.unwrap_or(SimOutcome::Completed { time: makespan });
        (SimResult { finish, makespan, linkdir_bytes, flows: flows_total, stats }, outcome)
    }
}
