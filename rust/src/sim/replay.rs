//! Warm-started delta-simulation for fault ensembles (DESIGN.md §16).
//!
//! Robust selection, Monte-Carlo fault ensembles, and workload fault
//! timelines re-simulate near-identical DAGs: every scenario is the
//! same task graph under a different set of capacity steps. Scenario
//! count (candidates × scenarios × systems) — not DAG size — is the
//! dominant cost. This module makes the per-scenario marginal cost
//! proportional to what the perturbation actually *changes*:
//!
//! 1. [`Baseline::record`] runs the unperturbed DAG once through
//!    [`Sim::run_event_driven_logged`], capturing the compact
//!    [`EventLog`](super::engine::EventLog) (per-task rate histories;
//!    finishes and activation instants are already implied by the
//!    result and the DAG).
//! 2. [`Baseline::replay`] classifies a perturbed scenario by its
//!    **divergence point** — the first surviving capacity step that
//!    touches a linkdir any flow ever crosses — and dispatches:
//!    - no such step: the baseline result verbatim (bit-exact, zero
//!      live events);
//!    - divergence at `t <= 0`, a stalled baseline, or the reference
//!      engine forced at record time: a **cold** re-run, bit-exact to
//!      a freshly composed simulation;
//!    - divergence at/after the baseline makespan: the perturbation
//!      can no longer affect anything — baseline verbatim, still
//!      `Completed` (a cold run never applies steps past completion);
//!    - genuine mid-run divergence: reconstruct the engine's settled
//!      state at the divergence instant from the log (finished tasks,
//!      in-flight flows with integrated residual bytes and last
//!      rates, pending latency/delay events) and resume **live**
//!      simulation there via [`Sim::run_event_driven_warm`].
//!
//! Replay invariants: completions due exactly at the divergence
//! instant happen under the baseline's old rates (exactly as a cold
//! run orders them); no refill is forced at resume — the first live
//! capacity step triggers one only if it lands on a loaded linkdir;
//! warm results agree with cold runs to the same ~1e-9 relative
//! contract the sharded driver carries, while the bit-exact modes
//! above are bitwise identical. `tests/faults_differential.rs` pins
//! warm-vs-cold agreement across every library × paper system ×
//! perturbation class.

use super::engine::{
    capacity_timeline, reference_forced, CapEvent, Event, EventLog, Sim, SimOutcome, SimResult,
    Task, TaskSpec, WarmFlow, WarmStart,
};
use super::TaskId;
use crate::topology::Topology;

/// How [`Baseline::replay`] will execute a given scenario.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum ReplayMode {
    /// No surviving capacity step touches a linkdir any flow crosses:
    /// the baseline result is returned verbatim. Bit-exact, zero live
    /// events.
    Identical,
    /// Divergence at `t <= 0`, a non-`Completed` baseline, or the
    /// reference engine forced at record time: cold re-run from the
    /// pristine DAG, bit-exact to a freshly composed simulation
    /// (including reference-engine dispatch).
    Cold,
    /// Divergence at or after the baseline makespan of a completed
    /// baseline: nothing left to perturb — baseline verbatim, still
    /// reported `Completed`.
    Tail,
    /// Genuine mid-run divergence: warm-started live simulation from
    /// the divergence instant.
    Warm,
}

/// A recorded unperturbed run: the pristine DAG, its result, and the
/// event log needed to reconstruct engine state at any instant.
pub(crate) struct Baseline<'t> {
    topo: &'t Topology,
    /// Pristine pre-run task clone (specs intact, `finish: None`).
    tasks: Vec<Task>,
    roots: Vec<TaskId>,
    result: SimResult,
    outcome: SimOutcome,
    log: EventLog,
    /// Per-task activation instant: max dependency finish plus the
    /// task's latency (flows) or duration (delays).
    fire: Vec<f64>,
    /// `used[ld]` — some positive-byte flow crosses linkdir `ld`.
    /// Steps on unused linkdirs cannot change any rate, settlement, or
    /// stall diagnosis, so they never count as divergence.
    used: Vec<bool>,
    /// Recorded under [`super::engine::with_reference_engine`]: every
    /// replay degrades to a cold `run_outcome` so differential tests
    /// still route all simulation through the reference core.
    cold_only: bool,
}

impl<'t> Baseline<'t> {
    /// Run the **unperturbed** DAG once, recording its event log.
    /// Panics if the builder already carries capacity events — a
    /// baseline is by definition the scenario with none.
    pub(crate) fn record(sim: Sim<'t>) -> Baseline<'t> {
        let Sim { topo, tasks, roots, cap_events } = sim;
        assert!(cap_events.is_empty(), "baseline must be unperturbed (got capacity events)");
        let pristine = tasks.clone();
        let cold_only = reference_forced();
        let mut log = EventLog::new(pristine.len());
        let run = Sim { topo, tasks, roots: roots.clone(), cap_events: Vec::new() };
        let (result, outcome) = if cold_only {
            run.run_outcome()
        } else {
            run.run_event_driven_logged(&mut log)
        };
        // Activation instants from the DAG + finish times: dependents
        // always have larger ids (Sim::push enforces it), so one
        // ascending pass settles every max-dependency-finish.
        let n = pristine.len();
        let mut ready = vec![0.0f64; n];
        for id in 0..n {
            let f = result.finish[id];
            for &d in &pristine[id].dependents {
                if f > ready[d] {
                    ready[d] = f;
                }
            }
        }
        let fire: Vec<f64> = (0..n)
            .map(|id| match pristine[id].spec {
                TaskSpec::Flow { latency, .. } => ready[id] + latency,
                TaskSpec::Delay { secs } => ready[id] + secs,
            })
            .collect();
        let mut used = vec![false; topo.links.len() * 2];
        for t in &pristine {
            if let TaskSpec::Flow { ref linkdirs, bytes, .. } = t.spec {
                if bytes > 0.0 {
                    for &ld in linkdirs {
                        used[ld] = true;
                    }
                }
            }
        }
        Baseline { topo, tasks: pristine, roots, result, outcome, log, fire, used, cold_only }
    }

    /// The topology the baseline was recorded over.
    pub(crate) fn topo(&self) -> &'t Topology {
        self.topo
    }

    /// The unperturbed run's result.
    pub(crate) fn result(&self) -> &SimResult {
        &self.result
    }

    /// The unperturbed run's terminal outcome.
    pub(crate) fn outcome(&self) -> &SimOutcome {
        &self.outcome
    }

    /// How [`Baseline::replay`] would execute this scenario.
    pub(crate) fn plan(&self, cap_events: &[CapEvent]) -> ReplayMode {
        self.classify(cap_events).0
    }

    /// Divergence classification: the mode, plus the divergence
    /// instant for [`ReplayMode::Warm`] (0.0 otherwise).
    fn classify(&self, cap_events: &[CapEvent]) -> (ReplayMode, f64) {
        if cap_events.is_empty() {
            return (ReplayMode::Identical, 0.0);
        }
        if self.cold_only || !self.outcome.is_completed() {
            return (ReplayMode::Cold, 0.0);
        }
        let timeline = capacity_timeline(self.topo, cap_events);
        let t_d = timeline.iter().find(|&&(_, ld, _)| self.used[ld]).map(|&(t, _, _)| t);
        match t_d {
            // every step was a bitwise no-op or touched only linkdirs
            // no flow crosses — neither can change anything
            None => (ReplayMode::Identical, 0.0),
            Some(t) if t <= 0.0 => (ReplayMode::Cold, t),
            Some(t) if t >= self.result.makespan => (ReplayMode::Tail, t),
            Some(t) => (ReplayMode::Warm, t),
        }
    }

    /// Execute the perturbed scenario, reusing as much of the baseline
    /// as its divergence point allows (module docs for the contract).
    pub(crate) fn replay(&self, cap_events: Vec<CapEvent>) -> (SimResult, SimOutcome) {
        let (mode, t_d) = self.classify(&cap_events);
        match mode {
            ReplayMode::Identical | ReplayMode::Tail => {
                (self.result.clone(), self.outcome.clone())
            }
            ReplayMode::Cold => self.replay_cold(cap_events),
            ReplayMode::Warm => {
                let warm = self.warm_start(t_d);
                let sim = Sim {
                    topo: self.topo,
                    tasks: self.tasks.clone(),
                    roots: self.roots.clone(),
                    cap_events,
                };
                sim.run_event_driven_warm(warm)
            }
        }
    }

    /// Cold re-run of the scenario from the pristine DAG — bit-exact
    /// to composing and running it fresh (the benchmark reference the
    /// differential suites and `make bench-delta` compare against).
    pub(crate) fn replay_cold(&self, cap_events: Vec<CapEvent>) -> (SimResult, SimOutcome) {
        let sim = Sim {
            topo: self.topo,
            tasks: self.tasks.clone(),
            roots: self.roots.clone(),
            cap_events,
        };
        // run_outcome, not run_event_driven: honors a forced reference
        // engine so differential routing stays airtight
        sim.run_outcome()
    }

    /// Reconstruct the engine's settled state at `t_d` from the log.
    fn warm_start(&self, t_d: f64) -> WarmStart {
        let n = self.tasks.len();
        let finish = &self.result.finish;
        let n_linkdirs = self.topo.links.len() * 2;
        let mut finished: Vec<(TaskId, f64)> = Vec::new();
        let mut deps_left: Vec<usize> = self.tasks.iter().map(|t| t.pending_deps).collect();
        let mut linkdir_bytes = vec![0.0; n_linkdirs];
        let mut flows_total = 0usize;
        // Completions due exactly at t_d happen under the old rates —
        // the same order a cold run delivers them in — so `<=` is the
        // correct boundary: divergence at a completion instant sees
        // that completion already settled.
        for id in 0..n {
            if finish[id] <= t_d {
                finished.push((id, finish[id]));
                for &d in &self.tasks[id].dependents {
                    deps_left[d] -= 1;
                }
                if let TaskSpec::Flow { ref linkdirs, bytes, .. } = self.tasks[id].spec {
                    if bytes > 0.0 {
                        flows_total += 1;
                        for &ld in linkdirs {
                            linkdir_bytes[ld] += bytes;
                        }
                    }
                }
            }
        }
        let mut flows: Vec<WarmFlow> = Vec::new();
        let mut events: Vec<(f64, Event)> = Vec::new();
        for id in 0..n {
            if finish[id] <= t_d || deps_left[id] != 0 {
                continue; // already settled, or not yet ready at t_d
            }
            let fire = self.fire[id];
            match self.tasks[id].spec {
                TaskSpec::Flow { ref linkdirs, bytes, .. }
                    if bytes > 0.0 && !linkdirs.is_empty() && fire <= t_d =>
                {
                    // In flight at t_d: integrate the piecewise-constant
                    // rate history up to t_d for the residual bytes, and
                    // carry the last recorded rate into the live run.
                    let recs = &self.log.rates[id];
                    let mut moved = 0.0f64;
                    let mut rate = 0.0f64;
                    for (i, &(t0, r)) in recs.iter().enumerate() {
                        if t0 > t_d {
                            break;
                        }
                        rate = r;
                        let t1 = recs.get(i + 1).map(|&(t, _)| t).unwrap_or(t_d).min(t_d);
                        if t1 > t0 {
                            moved += r * (t1 - t0);
                        }
                    }
                    let moved = moved.min(bytes);
                    for &ld in linkdirs {
                        linkdir_bytes[ld] += moved;
                    }
                    flows_total += 1;
                    flows.push(WarmFlow {
                        task: id,
                        remaining: bytes - moved,
                        rate,
                        linkdirs: linkdirs.clone(),
                    });
                }
                TaskSpec::Flow { .. } => {
                    // Ready but its latency has not elapsed (zero-byte
                    // and pathless flows finish at `fire`, so an
                    // unfinished one is always still waiting).
                    debug_assert!(fire > t_d, "ready flow unfinished past its fire instant");
                    events.push((fire, Event::Activate(id)));
                }
                TaskSpec::Delay { .. } => {
                    debug_assert!(fire > t_d, "ready delay unfinished past its fire instant");
                    events.push((fire, Event::DelayDone(id)));
                }
            }
        }
        events.sort_by(|a, b| {
            a.0.total_cmp(&b.0).then_with(|| event_task(&a.1).cmp(&event_task(&b.1)))
        });
        WarmStart { now: t_d, finished, flows_total, linkdir_bytes, flows, events }
    }
}

fn event_task(e: &Event) -> TaskId {
    match *e {
        Event::Activate(id) | Event::DelayDone(id) => id,
    }
}

/// Deterministic work-counter total for speedup accounting: the
/// engine's event + settlement + refill-visit counters, which measure
/// simulation work without wall-clock noise. BENCH artifacts record
/// cold/warm ratios of this so the delta-sim speedup is
/// byte-reproducible.
pub fn work_units(stats: &super::SimStats) -> u64 {
    stats.events + stats.completions + stats.settlements + stats.refill_flow_visits + stats.heap_pushes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::systems::SystemKind;
    use crate::topology::{DeviceKind, LinkClass, Topology};

    fn line_topo() -> Topology {
        let mut t = Topology::new("line");
        let g0 = t.add_device(DeviceKind::Gpu { rank: 0 }, 0, "g0");
        let g1 = t.add_device(DeviceKind::Gpu { rank: 1 }, 0, "g1");
        let g2 = t.add_device(DeviceKind::Gpu { rank: 2 }, 0, "g2");
        t.add_link(g0, g1, LinkClass::NvLink);
        t.add_link(g1, g2, LinkClass::NvLink);
        t
    }

    /// A contended DAG over the DGX-1 with dependencies and latency —
    /// the same shape the engine unit tests use.
    fn contended_dag(t: &Topology) -> Sim<'_> {
        let mut sim = Sim::new(t);
        let mut last = None;
        for a in 0..8usize {
            for b in 0..8usize {
                if a != b {
                    let p = t.route_gpus(a, b).unwrap();
                    let lat = t.path_latency(&p);
                    let deps: Vec<TaskId> =
                        if (a + b) % 3 == 0 { last.into_iter().collect() } else { vec![] };
                    last = Some(sim.flow(p, (a * 131 + b) as f64 * 1e6 + 1.0, lat, &deps));
                }
            }
        }
        sim
    }

    fn assert_close(a: &SimResult, b: &SimResult, label: &str) {
        let rel = (a.makespan - b.makespan).abs() / b.makespan.max(1e-300);
        assert!(rel < 1e-9, "{label}: makespan {} vs {}", a.makespan, b.makespan);
        assert_eq!(a.flows, b.flows, "{label}: flow count");
        for (i, (x, y)) in a.finish.iter().zip(&b.finish).enumerate() {
            assert!((x - y).abs() < 1e-11 + 1e-9 * y.abs(), "{label}: task {i}: {x} vs {y}");
        }
        for (ld, (x, y)) in a.linkdir_bytes.iter().zip(&b.linkdir_bytes).enumerate() {
            let denom = y.abs().max(1.0);
            assert!((x - y).abs() / denom < 1e-9, "{label}: linkdir {ld}: {x} vs {y}");
        }
    }

    #[test]
    fn identical_scenario_is_pure_replay_and_bit_exact() {
        let t = SystemKind::Dgx1.build();
        let baseline = Baseline::record(contended_dag(&t));
        assert_eq!(baseline.plan(&[]), ReplayMode::Identical);
        let (res, out) = baseline.replay(Vec::new());
        // zero live events: the returned result IS the baseline's —
        // same stats, every float bit-identical
        assert_eq!(res.stats, baseline.result().stats);
        assert_eq!(res.makespan.to_bits(), baseline.result().makespan.to_bits());
        for (a, b) in res.finish.iter().zip(&baseline.result().finish) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in res.linkdir_bytes.iter().zip(&baseline.result().linkdir_bytes) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(out.is_completed());
        // and bit-exact to a fresh cold run of the same DAG
        let fresh = contended_dag(&t).run();
        assert_eq!(res.makespan.to_bits(), fresh.makespan.to_bits());
    }

    #[test]
    fn zero_magnitude_steps_are_identical_mode() {
        // steps whose capacity equals the base bandwidth bit-for-bit
        // are filtered by the timeline; the plan must see no divergence
        let t = SystemKind::Dgx1.build();
        let baseline = Baseline::record(contended_dag(&t));
        let noops: Vec<CapEvent> = (0..t.links.len())
            .map(|l| CapEvent { time: 1.0e-6, link: l, capacity: t.links[l].class.bandwidth() })
            .collect();
        assert_eq!(baseline.plan(&noops), ReplayMode::Identical);
        let (res, _) = baseline.replay(noops);
        assert_eq!(res.makespan.to_bits(), baseline.result().makespan.to_bits());
    }

    #[test]
    fn divergence_at_t_zero_falls_back_to_cold_bit_exactly() {
        let t = SystemKind::Dgx1.build();
        let baseline = Baseline::record(contended_dag(&t));
        let hot = t.route_gpus(0, 1).unwrap().links[0];
        let step = CapEvent {
            time: 0.0,
            link: hot,
            capacity: 0.5 * t.links[hot].class.bandwidth(),
        };
        assert_eq!(baseline.plan(std::slice::from_ref(&step)), ReplayMode::Cold);
        let (res, out) = baseline.replay(vec![step]);
        let mut fresh = contended_dag(&t);
        fresh.cap_events.push(step);
        let (fres, fout) = fresh.run_outcome();
        assert_eq!(out, fout);
        assert_eq!(res.stats, fres.stats, "cold fallback must do identical work");
        assert_eq!(res.makespan.to_bits(), fres.makespan.to_bits());
        for (a, b) in res.finish.iter().zip(&fres.finish) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in res.linkdir_bytes.iter().zip(&fres.linkdir_bytes) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn warm_resume_agrees_with_cold_on_a_loaded_linkdir_step() {
        let t = SystemKind::Dgx1.build();
        let baseline = Baseline::record(contended_dag(&t));
        let hot = t.route_gpus(0, 1).unwrap().links[0];
        let t_d = 0.4 * baseline.result().makespan;
        let step =
            CapEvent { time: t_d, link: hot, capacity: 0.3 * t.links[hot].class.bandwidth() };
        assert_eq!(baseline.plan(std::slice::from_ref(&step)), ReplayMode::Warm);
        let (warm, wout) = baseline.replay(vec![step]);
        let (cold, cout) = baseline.replay_cold(vec![step]);
        assert!(wout.is_completed() && cout.is_completed());
        assert_close(&warm, &cold, "loaded-linkdir step");
        // the whole point: the warm run did strictly less work
        assert!(
            work_units(&warm.stats) < work_units(&cold.stats),
            "warm {} !< cold {}",
            work_units(&warm.stats),
            work_units(&cold.stats)
        );
    }

    #[test]
    fn divergence_exactly_at_a_completion_instant_agrees_with_cold() {
        let t = SystemKind::Dgx1.build();
        let baseline = Baseline::record(contended_dag(&t));
        // pick a completion instant strictly inside the run
        let makespan = baseline.result().makespan;
        let t_d = baseline
            .result()
            .finish
            .iter()
            .copied()
            .filter(|&f| f > 0.2 * makespan && f < 0.8 * makespan)
            .fold(f64::INFINITY, f64::min);
        assert!(t_d.is_finite(), "no interior completion to test against");
        let hot = t.route_gpus(2, 3).unwrap().links[0];
        let step =
            CapEvent { time: t_d, link: hot, capacity: 0.25 * t.links[hot].class.bandwidth() };
        assert_eq!(baseline.plan(std::slice::from_ref(&step)), ReplayMode::Warm);
        let (warm, _) = baseline.replay(vec![step]);
        let (cold, _) = baseline.replay_cold(vec![step]);
        assert_close(&warm, &cold, "completion-instant divergence");
    }

    #[test]
    fn idle_linkdir_divergence_forces_no_refill() {
        // A -> B serial chain on a line: link 1 is idle when the step
        // lands on it; the warm run must apply the step without a
        // refill and still agree with the cold run.
        let t = line_topo();
        let bw = LinkClass::NvLink.bandwidth();
        let bytes = 1.0e9;
        let build = |t: &Topology| {
            let mut sim = Sim::new(t);
            let a = sim.flow(t.route_gpus(0, 1).unwrap(), bytes, 0.0, &[]);
            let _b = sim.flow(t.route_gpus(1, 2).unwrap(), bytes, 0.0, &[a]);
            sim
        };
        let baseline = Baseline::record(build(&t));
        let t_d = 0.5 * bytes / bw; // halfway through flow A: link 1 idle
        let step = CapEvent { time: t_d, link: 1, capacity: 0.5 * bw };
        assert_eq!(baseline.plan(std::slice::from_ref(&step)), ReplayMode::Warm);
        let (warm, wout) = baseline.replay(vec![step]);
        assert!(wout.is_completed());
        assert_eq!(warm.stats.full_refills, 0, "idle-linkdir step forced a refill");
        let (cold, _) = baseline.replay_cold(vec![step]);
        assert_close(&warm, &cold, "idle-linkdir step");
        // exact closed form: B runs after A at the halved capacity
        let expect = bytes / bw + bytes / (0.5 * bw);
        assert!((warm.makespan - expect).abs() / expect < 1e-9, "{}", warm.makespan);
    }

    #[test]
    fn permanent_outage_after_makespan_still_reports_completed() {
        let t = SystemKind::Dgx1.build();
        let baseline = Baseline::record(contended_dag(&t));
        let hot = t.route_gpus(0, 1).unwrap().links[0];
        let step = CapEvent {
            time: 2.0 * baseline.result().makespan,
            link: hot,
            capacity: 0.0,
        };
        assert_eq!(baseline.plan(std::slice::from_ref(&step)), ReplayMode::Tail);
        let (res, out) = baseline.replay(vec![step]);
        assert!(out.is_completed(), "post-makespan outage flipped the outcome: {out:?}");
        assert_eq!(res.makespan.to_bits(), baseline.result().makespan.to_bits());
        // a cold run never reaches the step either
        let (cold, cout) = baseline.replay_cold(vec![step]);
        assert!(cout.is_completed());
        assert_eq!(res.makespan.to_bits(), cold.makespan.to_bits());
    }

    #[test]
    fn mid_run_outage_stalls_identically_warm_and_cold() {
        let t = line_topo();
        let bw = LinkClass::NvLink.bandwidth();
        let bytes = 1.0e9;
        let build = |t: &Topology| {
            let mut sim = Sim::new(t);
            sim.flow(t.route_gpus(0, 1).unwrap(), bytes, 0.0, &[]);
            sim
        };
        let baseline = Baseline::record(build(&t));
        let t_d = 0.25 * bytes / bw;
        let step = CapEvent { time: t_d, link: 0, capacity: 0.0 };
        assert_eq!(baseline.plan(std::slice::from_ref(&step)), ReplayMode::Warm);
        let (warm, wout) = baseline.replay(vec![step]);
        let (cold, cout) = baseline.replay_cold(vec![step]);
        let (SimOutcome::Stalled { time: wt, culprit_links: wl, .. },
             SimOutcome::Stalled { time: ct, culprit_links: cl, .. }) = (&wout, &cout)
        else {
            panic!("outage did not stall: warm {wout:?} cold {cout:?}");
        };
        assert!((wt - ct).abs() < 1e-11 + 1e-9 * ct.abs());
        assert_eq!(wl, cl);
        // delivered bytes before the stall agree too
        for (a, b) in warm.linkdir_bytes.iter().zip(&cold.linkdir_bytes) {
            let denom = b.abs().max(1.0);
            assert!((a - b).abs() / denom < 1e-9);
        }
    }

    #[test]
    fn reference_override_degrades_to_cold_bit_exactly() {
        use crate::sim::with_reference_engine;
        let t = SystemKind::Dgx1.build();
        let hot = t.route_gpus(0, 1).unwrap().links[0];
        let step = CapEvent {
            time: 1.0e-4,
            link: hot,
            capacity: 0.5 * t.links[hot].class.bandwidth(),
        };
        let (via_replay, via_fresh) = with_reference_engine(|| {
            let baseline = Baseline::record(contended_dag(&t));
            assert_eq!(baseline.plan(std::slice::from_ref(&step)), ReplayMode::Cold);
            let (r, _) = baseline.replay(vec![step]);
            let mut fresh = contended_dag(&t);
            fresh.cap_events.push(step);
            let (f, _) = fresh.run_outcome();
            (r, f)
        });
        assert_eq!(via_replay.stats, Default::default(), "reference stats are all-zero");
        assert_eq!(via_replay.makespan.to_bits(), via_fresh.makespan.to_bits());
        for (a, b) in via_replay.finish.iter().zip(&via_fresh.finish) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
