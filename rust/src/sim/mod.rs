//! Deterministic discrete-event flow simulator.
//!
//! Communication library models (comm/) describe a collective as a DAG of
//! *tasks*: point-to-point flows along topology paths, plus pure delays
//! (API launch overheads, protocol handshakes). The engine executes the
//! DAG in virtual time with **max-min fair bandwidth sharing** on every
//! (link, direction) pair — concurrent flows crossing the same PCIe
//! switch or IB uplink slow each other down exactly as they do on the
//! paper's systems (the CS-Storm's shared PCIe switches at 16 GPUs being
//! the headline example, §V-B).
//!
//! Fidelity notes:
//! - links are full duplex; each direction has independent capacity;
//! - a flow's bytes start moving `latency` seconds after its dependencies
//!   complete (per-hop wire latency + any protocol overhead the comm
//!   model adds);
//! - rates are recomputed with progressive filling whenever a flow starts
//!   or finishes **or a scheduled capacity step fires**
//!   ([`Sim::capacity_event`], the fault/variability substrate of
//!   DESIGN.md §12) — piecewise-constant max-min rates between events.
//!
//! Two interchangeable cores execute the DAG:
//! - [`engine`] — the event-driven engine (completion-prediction heap,
//!   lazy byte settlement, incremental max-min; DESIGN.md §8). This is
//!   what [`Sim::run`] uses.
//! - [`reference`] — the pre-rewrite O(F²·L) core, retained as a
//!   differential-testing oracle ([`Sim::run_reference`], or route whole
//!   comm models through it with [`engine::with_reference_engine`]).
//!
//! At thousand-rank scale a third driver sits *above* the engine:
//! [`sharded`] partitions the DAG by link locality into independent
//! components and runs each bucket of components on its own pool worker
//! (DESIGN.md §15) — 1e-9-identical to the unsharded engine, pinned
//! three ways by `tests/scale_differential.rs`. [`scale`] packages the
//! deterministic scale-study cases the engine bench and the CI scale
//! step share.
//!
//! For fault *ensembles* — many perturbed variants of one DAG — the
//! [`replay`] module adds warm-started delta-simulation (DESIGN.md
//! §16): record an unperturbed baseline once ([`replay::Baseline`]),
//! then re-run each perturbed scenario by fast-forwarding the
//! baseline's event log to the scenario's first divergence point and
//! simulating live only from there. 1e-9-identical to cold runs, and
//! bit-exact whenever the scenario cannot diverge at all.

pub mod engine;
pub mod reference;
pub mod replay;
pub mod scale;
pub mod sharded;

pub use engine::{with_reference_engine, Sim, SimOutcome, SimResult, SimStats, TaskId};
pub use sharded::{run_sharded, ShardReport};

pub(crate) use replay::Baseline;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{DeviceKind, LinkClass, Topology};

    fn line_topo() -> Topology {
        // g0 -- g1 -- g2 over NVLink
        let mut t = Topology::new("line");
        let g0 = t.add_device(DeviceKind::Gpu { rank: 0 }, 0, "g0");
        let g1 = t.add_device(DeviceKind::Gpu { rank: 1 }, 0, "g1");
        let g2 = t.add_device(DeviceKind::Gpu { rank: 2 }, 0, "g2");
        t.add_link(g0, g1, LinkClass::NvLink);
        t.add_link(g1, g2, LinkClass::NvLink);
        t
    }

    #[test]
    fn single_flow_time_is_latency_plus_bytes_over_bw() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let path = t.route_gpus(0, 1).unwrap();
        let bytes = 1.0e9;
        let lat = t.path_latency(&path);
        let id = sim.flow(path, bytes, lat, &[]);
        let res = sim.run();
        let expect = lat + bytes / LinkClass::NvLink.bandwidth();
        assert!(
            (res.finish(id) - expect).abs() / expect < 1e-9,
            "{} vs {}",
            res.finish(id),
            expect
        );
    }

    #[test]
    fn two_flows_share_a_link_fairly() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let path = t.route_gpus(0, 1).unwrap();
        let bytes = 1.0e9;
        let a = sim.flow(path.clone(), bytes, 0.0, &[]);
        let b = sim.flow(path, bytes, 0.0, &[]);
        let res = sim.run();
        // both finish together at 2x the solo time
        let solo = bytes / LinkClass::NvLink.bandwidth();
        assert!((res.finish(a) - 2.0 * solo).abs() / solo < 1e-9);
        assert!((res.finish(b) - 2.0 * solo).abs() / solo < 1e-9);
    }

    #[test]
    fn opposite_directions_do_not_contend() {
        // full duplex: g0->g1 and g1->g0 each get the full link
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let fwd = t.route_gpus(0, 1).unwrap();
        let bwd = t.route_gpus(1, 0).unwrap();
        let bytes = 1.0e9;
        let a = sim.flow(fwd, bytes, 0.0, &[]);
        let b = sim.flow(bwd, bytes, 0.0, &[]);
        let res = sim.run();
        let solo = bytes / LinkClass::NvLink.bandwidth();
        assert!((res.finish(a) - solo).abs() / solo < 1e-9);
        assert!((res.finish(b) - solo).abs() / solo < 1e-9);
    }

    #[test]
    fn dependencies_serialize() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let path = t.route_gpus(0, 1).unwrap();
        let bytes = 1.0e9;
        let a = sim.flow(path.clone(), bytes, 0.0, &[]);
        let b = sim.flow(path, bytes, 0.0, &[a]);
        let res = sim.run();
        let solo = bytes / LinkClass::NvLink.bandwidth();
        assert!((res.finish(b) - 2.0 * solo).abs() / solo < 1e-9);
    }

    #[test]
    fn multi_hop_bottleneck() {
        // one flow across both hops, a second on the first hop only:
        // first hop is shared (1/2 rate) and is the bottleneck.
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let long = t.route_gpus(0, 2).unwrap();
        let short = t.route_gpus(0, 1).unwrap();
        let bytes = 1.0e9;
        let a = sim.flow(long, bytes, 0.0, &[]);
        let _b = sim.flow(short, bytes, 0.0, &[]);
        let res = sim.run();
        let solo = bytes / LinkClass::NvLink.bandwidth();
        // flow a: shares hop0 until b finishes... both at 0.5 rate; they
        // finish hop-0 bytes together; a is limited to 0.5 throughout its
        // life until b completes (at 2*solo both have moved all bytes).
        assert!((res.finish(a) - 2.0 * solo).abs() / solo < 1e-6);
    }

    #[test]
    fn delay_task_and_chain() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let d = sim.delay(5.0e-6, &[]);
        let path = t.route_gpus(0, 1).unwrap();
        let f = sim.flow(path, 1.0e6, 0.0, &[d]);
        let res = sim.run();
        let expect = 5.0e-6 + 1.0e6 / LinkClass::NvLink.bandwidth();
        assert!((res.finish(f) - expect).abs() < 1e-12);
    }

    #[test]
    fn zero_byte_flow_completes_at_latency() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let path = t.route_gpus(0, 1).unwrap();
        let f = sim.flow(path, 0.0, 2.0e-6, &[]);
        let res = sim.run();
        assert!((res.finish(f) - 2.0e-6).abs() < 1e-15);
    }

    #[test]
    fn makespan_is_max_finish() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let p01 = t.route_gpus(0, 1).unwrap();
        let p12 = t.route_gpus(1, 2).unwrap();
        let a = sim.flow(p01, 2.0e9, 0.0, &[]);
        let b = sim.flow(p12, 1.0e9, 0.0, &[]);
        let res = sim.run();
        assert_eq!(res.makespan, res.finish(a).max(res.finish(b)));
    }

    /// Every unit-test scenario above, plus a contended all-pairs DAG,
    /// must agree between the event-driven engine and the pre-rewrite
    /// reference core. Settlement order differs (lazy vs per-event), so
    /// agreement is to tight relative tolerance, not bit-for-bit — see
    /// the numerical contract note in [`super::reference`].
    #[test]
    fn engines_agree_on_contended_dag() {
        let t = crate::topology::systems::dgx1();
        let build = |t: &crate::topology::Topology| {
            let mut sim = Sim::new(t);
            let mut last = None;
            for a in 0..8usize {
                for b in 0..8usize {
                    if a != b {
                        let p = t.route_gpus(a, b).unwrap();
                        let lat = t.path_latency(&p);
                        let deps: Vec<TaskId> = if (a + b) % 3 == 0 {
                            last.into_iter().collect()
                        } else {
                            vec![]
                        };
                        last = Some(sim.flow(p, (a * 131 + b) as f64 * 1e6 + 1.0, lat, &deps));
                    }
                }
            }
            sim
        };
        let new = build(&t).run();
        let old = build(&t).run_reference();
        assert_eq!(new.flows, old.flows);
        let rel = (new.makespan - old.makespan).abs() / old.makespan;
        assert!(rel < 1e-9, "makespan diverged: {} vs {}", new.makespan, old.makespan);
        for (i, (a, b)) in new.finish_times().iter().zip(old.finish_times()).enumerate() {
            // mixed tolerance: the reference core's 1e-6-byte early-
            // completion window shifts finishes absolutely, not relatively
            assert!((a - b).abs() < 1e-11 + 1e-9 * b.abs(), "task {i}: {a} vs {b}");
        }
        for (ld, (a, b)) in new.linkdir_bytes.iter().zip(&old.linkdir_bytes).enumerate() {
            let denom = b.abs().max(1.0);
            assert!((a - b).abs() / denom < 1e-6, "linkdir {ld}: {a} vs {b}");
        }
    }

    /// `with_reference_engine` must reroute `Sim::run` on this thread
    /// (and restore the default afterwards): the reference core reports
    /// all-zero stats while the event engine counts its work.
    #[test]
    fn reference_override_is_scoped() {
        let t = line_topo();
        let run_once = || {
            let mut sim = Sim::new(&t);
            let path = t.route_gpus(0, 1).unwrap();
            sim.flow(path, 1.0e9, 0.0, &[]);
            sim.run()
        };
        let via_ref = crate::sim::with_reference_engine(&run_once);
        assert_eq!(via_ref.stats, Default::default());
        let via_event = run_once();
        assert!(via_event.stats.heap_pushes > 0, "override leaked out of scope");
        assert!((via_ref.makespan - via_event.makespan).abs() / via_event.makespan < 1e-9);
    }

    /// One flow over one link crossing a capacity step: the finish time
    /// is the exact two-segment integral, on both engines.
    #[test]
    fn capacity_step_single_flow_two_segments() {
        let t = line_topo();
        let bw = LinkClass::NvLink.bandwidth();
        let bytes = 1.0e9;
        let t1 = 0.02;
        let new_bw = 0.5 * bw;
        let expect = t1 + (bytes - bw * t1) / new_bw;
        for reference in [false, true] {
            let mut sim = Sim::new(&t);
            let path = t.route_gpus(0, 1).unwrap();
            let id = sim.flow(path.clone(), bytes, 0.0, &[]);
            sim.capacity_event(path.links[0], t1, new_bw);
            let res = if reference { sim.run_reference() } else { sim.run() };
            assert!(
                (res.finish(id) - expect).abs() / expect < 1e-9,
                "ref={reference}: {} vs {expect}",
                res.finish(id)
            );
            // conservation: the link carried exactly the flow's bytes
            let carried = res.link_bytes(path.links[0]);
            assert!((carried - bytes).abs() / bytes < 1e-9, "carried {carried}");
        }
    }

    /// Degrade-then-restore window: three exact rate segments.
    #[test]
    fn capacity_window_restores() {
        let t = line_topo();
        let bw = LinkClass::NvLink.bandwidth();
        let bytes = 2.0e9;
        let (t1, t2) = (0.01, 0.03);
        let low = 0.25 * bw;
        let mut sim = Sim::new(&t);
        let path = t.route_gpus(0, 1).unwrap();
        let id = sim.flow(path.clone(), bytes, 0.0, &[]);
        sim.capacity_event(path.links[0], t1, low);
        sim.capacity_event(path.links[0], t2, bw);
        let res = sim.run();
        let moved = bw * t1 + low * (t2 - t1);
        let expect = t2 + (bytes - moved) / bw;
        assert!(
            (res.finish(id) - expect).abs() / expect < 1e-9,
            "{} vs {expect}",
            res.finish(id)
        );
        assert_eq!(res.stats.cap_events, 4, "2 steps x 2 directions");
    }

    /// A capacity step whose value equals the link's current capacity
    /// bit-for-bit is filtered before the run: results AND work counters
    /// are bitwise identical to a run with no events at all — the
    /// zero-perturbation differential contract.
    #[test]
    fn zero_magnitude_capacity_event_is_bitwise_noop() {
        let t = crate::topology::systems::dgx1();
        let build = |events: bool| {
            let mut sim = Sim::new(&t);
            let mut last = None;
            for a in 0..8usize {
                let b = (a + 3) % 8;
                let p = t.route_gpus(a, b).unwrap();
                let lat = t.path_latency(&p);
                let deps: Vec<TaskId> =
                    if a % 2 == 0 { last.into_iter().collect() } else { vec![] };
                last = Some(sim.flow(p, (a + 1) as f64 * 3.0e7, lat, &deps));
            }
            if events {
                for l in 0..t.links.len() {
                    let base = t.links[l].class.bandwidth();
                    sim.capacity_event(l, 1.0e-6, 1.0 * base); // scale 1.0
                    sim.capacity_event(l, 2.0e-6, base.min(f64::MAX)); // floor above base
                }
            }
            sim
        };
        let plain = build(false).run();
        let noop = build(true).run();
        assert_eq!(plain.stats, noop.stats, "no-op events leaked work into the engine");
        assert_eq!(plain.makespan.to_bits(), noop.makespan.to_bits());
        for (a, b) in plain.finish_times().iter().zip(noop.finish_times()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in plain.linkdir_bytes.iter().zip(&noop.linkdir_bytes) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // and the reference core likewise
        let rp = build(false).run_reference();
        let rn = build(true).run_reference();
        assert_eq!(rp.makespan.to_bits(), rn.makespan.to_bits());
    }

    /// Both engines under genuine capacity steps on a contended DAG:
    /// agreement to the documented ~1e-9 relative contract.
    #[test]
    fn engines_agree_under_capacity_steps() {
        let t = crate::topology::systems::dgx1();
        let build = |t: &crate::topology::Topology| {
            let mut sim = Sim::new(t);
            let mut last = None;
            for a in 0..8usize {
                for b in 0..8usize {
                    if a != b {
                        let p = t.route_gpus(a, b).unwrap();
                        let lat = t.path_latency(&p);
                        let deps: Vec<TaskId> = if (a + b) % 3 == 0 {
                            last.into_iter().collect()
                        } else {
                            vec![]
                        };
                        last = Some(sim.flow(p, (a * 131 + b) as f64 * 1e6 + 1.0, lat, &deps));
                    }
                }
            }
            for l in 0..t.links.len() {
                if l % 3 == 0 {
                    let base = t.links[l].class.bandwidth();
                    sim.capacity_event(l, 1.0e-4 * (l + 1) as f64, 0.4 * base);
                    sim.capacity_event(l, 3.0e-3, base);
                }
            }
            sim
        };
        let new = build(&t).run();
        let old = build(&t).run_reference();
        assert_eq!(new.flows, old.flows);
        assert!(new.stats.cap_events > 0, "steps did not fire");
        let rel = (new.makespan - old.makespan).abs() / old.makespan;
        assert!(rel < 1e-9, "makespan diverged: {} vs {}", new.makespan, old.makespan);
        for (i, (a, b)) in new.finish_times().iter().zip(old.finish_times()).enumerate() {
            assert!((a - b).abs() < 1e-11 + 1e-9 * b.abs(), "task {i}: {a} vs {b}");
        }
        for (ld, (a, b)) in new.linkdir_bytes.iter().zip(&old.linkdir_bytes).enumerate() {
            let denom = b.abs().max(1.0);
            assert!((a - b).abs() / denom < 1e-6, "linkdir {ld}: {a} vs {b}");
        }
    }

    #[test]
    #[should_panic(expected = "capacity must be finite and non-negative")]
    fn capacity_event_rejects_negative_capacity() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        sim.capacity_event(0, 0.0, -1.0);
    }

    /// Zero capacity is legal (the outage substrate, DESIGN.md §14): a
    /// flow crossing a dead link freezes; `run_outcome` diagnoses the
    /// stall with the culprit link instead of hanging, on both engines,
    /// and the stall time/diagnosis agree across the cores.
    #[test]
    fn dead_link_stalls_with_diagnosis_on_both_engines() {
        let t = line_topo();
        let bw = LinkClass::NvLink.bandwidth();
        let bytes = 1.0e9;
        let t_down = 0.01;
        let build = || {
            let mut sim = Sim::new(&t);
            let p01 = t.route_gpus(0, 1).unwrap();
            let p12 = t.route_gpus(1, 2).unwrap();
            let a = sim.flow(p01, bytes, 0.0, &[]);
            let _chained = sim.flow(p12.clone(), bytes, 0.0, &[a]);
            let b = sim.flow(p12, bytes, 0.0, &[]);
            // link 0 dies mid-flight and never recovers; link 1 is fine
            sim.capacity_event(0, t_down, 0.0);
            (sim, b)
        };
        let (sim, b) = build();
        let (res, outcome) = sim.run_outcome();
        let (sim_r, _) = build();
        let (res_r, outcome_r) = sim_r.run_reference_outcome();
        for (label, res, out) in [("event", &res, &outcome), ("reference", &res_r, &outcome_r)] {
            let SimOutcome::Stalled { time, stuck_tasks, starved_flows, culprit_links } = out
            else {
                panic!("{label}: dead link did not stall: {out:?}");
            };
            assert!(time.is_finite() && *time >= t_down, "{label}: stall time {time}");
            assert_eq!(culprit_links, &vec![0usize], "{label}");
            assert_eq!(*starved_flows, 1, "{label}");
            // flow a and its dependent are stuck; flow b completed
            assert_eq!(stuck_tasks, &vec![0usize, 1], "{label}");
            let solo = bytes / bw;
            assert!((res.finish(b) - solo).abs() / solo < 1e-6, "{label}: {}", res.finish(b));
            assert!(res.makespan.is_finite() && res.finish_times().iter().all(|f| f.is_finite()));
            // delivered bytes: link 0 carried only what moved before the
            // outage; link 1 carried exactly flow b's bytes
            assert!((res.link_bytes(0) - bw * t_down).abs() / (bw * t_down) < 1e-6, "{label}");
            assert!((res.link_bytes(1) - bytes).abs() / bytes < 1e-6, "{label}");
        }
        // cross-engine agreement on the stall instant
        let rel = (outcome.time() - outcome_r.time()).abs() / outcome_r.time();
        assert!(rel < 1e-9, "stall times diverged: {} vs {}", outcome.time(), outcome_r.time());
    }

    /// A dead link whose capacity is restored by a later step is *not* a
    /// stall: the pending step revives the frozen flow and the finish
    /// time is the exact two-segment integral around the dead window.
    #[test]
    fn outage_window_revives_frozen_flow() {
        let t = line_topo();
        let bw = LinkClass::NvLink.bandwidth();
        let bytes = 2.0e9;
        let (t1, t2) = (0.01, 0.04);
        for reference in [false, true] {
            let mut sim = Sim::new(&t);
            let path = t.route_gpus(0, 1).unwrap();
            let id = sim.flow(path.clone(), bytes, 0.0, &[]);
            sim.capacity_event(path.links[0], t1, 0.0);
            sim.capacity_event(path.links[0], t2, bw);
            let (res, outcome) = if reference {
                sim.run_reference_outcome()
            } else {
                sim.run_outcome()
            };
            assert!(outcome.is_completed(), "ref={reference}: {outcome:?}");
            let expect = t2 + (bytes - bw * t1) / bw;
            assert!(
                (res.finish(id) - expect).abs() / expect < 1e-9,
                "ref={reference}: {} vs {expect}",
                res.finish(id)
            );
        }
    }

    /// `run_outcome` on a completing DAG is bit-identical to `run` —
    /// results *and* work counters (the liveness machinery costs
    /// nothing when it never triggers).
    #[test]
    fn run_outcome_is_bit_exact_to_run_when_completed() {
        let t = crate::topology::systems::dgx1();
        let build = || {
            let mut sim = Sim::new(&t);
            let mut last = None;
            for a in 0..8usize {
                let b = (a + 3) % 8;
                let p = t.route_gpus(a, b).unwrap();
                let lat = t.path_latency(&p);
                let deps: Vec<TaskId> =
                    if a % 2 == 0 { last.into_iter().collect() } else { vec![] };
                last = Some(sim.flow(p, (a + 1) as f64 * 3.0e7, lat, &deps));
            }
            sim
        };
        let plain = build().run();
        let (via_outcome, outcome) = build().run_outcome();
        assert_eq!(outcome, SimOutcome::Completed { time: plain.makespan });
        assert_eq!(plain.stats, via_outcome.stats);
        assert_eq!(plain.makespan.to_bits(), via_outcome.makespan.to_bits());
        for (a, b) in plain.finish_times().iter().zip(via_outcome.finish_times()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "time must be finite and non-negative")]
    fn capacity_event_rejects_negative_time() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        sim.capacity_event(0, -1.0, 1.0e9);
    }

    #[test]
    fn determinism_across_runs() {
        let t = crate::topology::systems::dgx1();
        let build = || {
            let mut sim = Sim::new(&t);
            let mut last = None;
            for a in 0..8usize {
                for b in 0..8usize {
                    if a != b {
                        let p = t.route_gpus(a, b).unwrap();
                        let lat = t.path_latency(&p);
                        let deps: Vec<TaskId> = last.into_iter().collect();
                        last = Some(sim.flow(p, (a * 131 + b) as f64 * 1e6, lat, &deps));
                    }
                }
            }
            sim.run().makespan
        };
        assert_eq!(build().to_bits(), build().to_bits());
    }
}
