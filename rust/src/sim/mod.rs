//! Deterministic discrete-event flow simulator.
//!
//! Communication library models (comm/) describe a collective as a DAG of
//! *tasks*: point-to-point flows along topology paths, plus pure delays
//! (API launch overheads, protocol handshakes). The engine executes the
//! DAG in virtual time with **max-min fair bandwidth sharing** on every
//! (link, direction) pair — concurrent flows crossing the same PCIe
//! switch or IB uplink slow each other down exactly as they do on the
//! paper's systems (the CS-Storm's shared PCIe switches at 16 GPUs being
//! the headline example, §V-B).
//!
//! Fidelity notes:
//! - links are full duplex; each direction has independent capacity;
//! - a flow's bytes start moving `latency` seconds after its dependencies
//!   complete (per-hop wire latency + any protocol overhead the comm
//!   model adds);
//! - rates are recomputed with progressive filling whenever a flow starts
//!   or finishes — piecewise-constant max-min rates between events.

pub mod engine;

pub use engine::{Sim, SimResult, TaskId};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{DeviceKind, LinkClass, Topology};

    fn line_topo() -> Topology {
        // g0 -- g1 -- g2 over NVLink
        let mut t = Topology::new("line");
        let g0 = t.add_device(DeviceKind::Gpu { rank: 0 }, 0, "g0");
        let g1 = t.add_device(DeviceKind::Gpu { rank: 1 }, 0, "g1");
        let g2 = t.add_device(DeviceKind::Gpu { rank: 2 }, 0, "g2");
        t.add_link(g0, g1, LinkClass::NvLink);
        t.add_link(g1, g2, LinkClass::NvLink);
        t
    }

    #[test]
    fn single_flow_time_is_latency_plus_bytes_over_bw() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let path = t.route_gpus(0, 1).unwrap();
        let bytes = 1.0e9;
        let lat = t.path_latency(&path);
        let id = sim.flow(path, bytes, lat, &[]);
        let res = sim.run();
        let expect = lat + bytes / LinkClass::NvLink.bandwidth();
        assert!(
            (res.finish(id) - expect).abs() / expect < 1e-9,
            "{} vs {}",
            res.finish(id),
            expect
        );
    }

    #[test]
    fn two_flows_share_a_link_fairly() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let path = t.route_gpus(0, 1).unwrap();
        let bytes = 1.0e9;
        let a = sim.flow(path.clone(), bytes, 0.0, &[]);
        let b = sim.flow(path, bytes, 0.0, &[]);
        let res = sim.run();
        // both finish together at 2x the solo time
        let solo = bytes / LinkClass::NvLink.bandwidth();
        assert!((res.finish(a) - 2.0 * solo).abs() / solo < 1e-9);
        assert!((res.finish(b) - 2.0 * solo).abs() / solo < 1e-9);
    }

    #[test]
    fn opposite_directions_do_not_contend() {
        // full duplex: g0->g1 and g1->g0 each get the full link
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let fwd = t.route_gpus(0, 1).unwrap();
        let bwd = t.route_gpus(1, 0).unwrap();
        let bytes = 1.0e9;
        let a = sim.flow(fwd, bytes, 0.0, &[]);
        let b = sim.flow(bwd, bytes, 0.0, &[]);
        let res = sim.run();
        let solo = bytes / LinkClass::NvLink.bandwidth();
        assert!((res.finish(a) - solo).abs() / solo < 1e-9);
        assert!((res.finish(b) - solo).abs() / solo < 1e-9);
    }

    #[test]
    fn dependencies_serialize() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let path = t.route_gpus(0, 1).unwrap();
        let bytes = 1.0e9;
        let a = sim.flow(path.clone(), bytes, 0.0, &[]);
        let b = sim.flow(path, bytes, 0.0, &[a]);
        let res = sim.run();
        let solo = bytes / LinkClass::NvLink.bandwidth();
        assert!((res.finish(b) - 2.0 * solo).abs() / solo < 1e-9);
    }

    #[test]
    fn multi_hop_bottleneck() {
        // one flow across both hops, a second on the first hop only:
        // first hop is shared (1/2 rate) and is the bottleneck.
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let long = t.route_gpus(0, 2).unwrap();
        let short = t.route_gpus(0, 1).unwrap();
        let bytes = 1.0e9;
        let a = sim.flow(long, bytes, 0.0, &[]);
        let _b = sim.flow(short, bytes, 0.0, &[]);
        let res = sim.run();
        let solo = bytes / LinkClass::NvLink.bandwidth();
        // flow a: shares hop0 until b finishes... both at 0.5 rate; they
        // finish hop-0 bytes together; a is limited to 0.5 throughout its
        // life until b completes (at 2*solo both have moved all bytes).
        assert!((res.finish(a) - 2.0 * solo).abs() / solo < 1e-6);
    }

    #[test]
    fn delay_task_and_chain() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let d = sim.delay(5.0e-6, &[]);
        let path = t.route_gpus(0, 1).unwrap();
        let f = sim.flow(path, 1.0e6, 0.0, &[d]);
        let res = sim.run();
        let expect = 5.0e-6 + 1.0e6 / LinkClass::NvLink.bandwidth();
        assert!((res.finish(f) - expect).abs() < 1e-12);
    }

    #[test]
    fn zero_byte_flow_completes_at_latency() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let path = t.route_gpus(0, 1).unwrap();
        let f = sim.flow(path, 0.0, 2.0e-6, &[]);
        let res = sim.run();
        assert!((res.finish(f) - 2.0e-6).abs() < 1e-15);
    }

    #[test]
    fn makespan_is_max_finish() {
        let t = line_topo();
        let mut sim = Sim::new(&t);
        let p01 = t.route_gpus(0, 1).unwrap();
        let p12 = t.route_gpus(1, 2).unwrap();
        let a = sim.flow(p01, 2.0e9, 0.0, &[]);
        let b = sim.flow(p12, 1.0e9, 0.0, &[]);
        let res = sim.run();
        assert_eq!(res.makespan, res.finish(a).max(res.finish(b)));
    }

    #[test]
    fn determinism_across_runs() {
        let t = crate::topology::systems::dgx1();
        let build = || {
            let mut sim = Sim::new(&t);
            let mut last = None;
            for a in 0..8usize {
                for b in 0..8usize {
                    if a != b {
                        let p = t.route_gpus(a, b).unwrap();
                        let lat = t.path_latency(&p);
                        let deps: Vec<TaskId> = last.into_iter().collect();
                        last = Some(sim.flow(p, (a * 131 + b) as f64 * 1e6, lat, &deps));
                    }
                }
            }
            sim.run().makespan
        };
        assert_eq!(build().to_bits(), build().to_bits());
    }
}
