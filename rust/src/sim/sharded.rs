//! Sharded driver over the event engine (DESIGN.md §15): partition the
//! flow graph by **link locality** and fan the pieces across
//! [`crate::util::pool`] workers.
//!
//! Max-min fair sharing couples two flows only when their link sets
//! overlap (directly or transitively), and dependencies couple tasks
//! only along DAG edges. Both relations are *local*, so the union of
//! {task—dependent edges} ∪ {flow—link incidences} splits the simulation
//! into connected components that provably never exchange bytes, rates
//! or events. Each component is an independent simulation; components
//! are bucketed round-robin into shard [`Sim`]s and every shard runs the
//! unmodified PR-2 event engine on its own worker.
//!
//! - Flows whose link sets stay within one component never synchronize
//!   with the rest of the run — they pay no cross-shard coordination at
//!   all (there are no locks; shards share nothing but the read-only
//!   topology).
//! - A flow whose link set touches two components *merges* them: the
//!   union-find closes over its incidences, so the "merged shard"
//!   fallback of the design is simply the component the flow welds
//!   together. Worst case (one flow crossing every link) degenerates to
//!   a single shard — exactly the unsharded engine.
//! - Capacity steps ride with their link's component; steps on links no
//!   flow ever crosses are parked on shard 0 (they cannot affect any
//!   rate).
//!
//! Shard bookkeeping is flat SoA arrays (union-find parent/size arena,
//! `shard_of`/`local_id` maps) — no per-task allocation beyond the task
//! specs themselves, which are *moved* into their shard, not cloned.
//!
//! **Numerical contract**: per-component arithmetic is identical to the
//! unsharded engine, but the unsharded progressive-filling refill takes
//! its fair-share increment as a min over *all* loaded linkdirs — across
//! components — so low-order bits can differ whenever unrelated
//! components are concurrently active. Results agree to 1e-9 relative
//! (`tests/scale_differential.rs` pins sharded vs unsharded vs
//! `sim/reference.rs` three ways); they are *not* promised bit-identical
//! to the unsharded run. Shard *count* does not change which flows
//! couple, only how components are bucketed.

use super::engine::{Sim, SimOutcome, SimResult, SimStats, Task, TaskSpec};
use crate::util::pool;

/// Union-find over tasks + links, SoA (parent/size arenas), path
/// halving + union by size.
struct Uf {
    parent: Vec<u32>,
    size: Vec<u32>,
}

impl Uf {
    fn new(n: usize) -> Uf {
        assert!(n < u32::MAX as usize, "shard planner supports < 2^32 tasks+links");
        Uf { parent: (0..n as u32).collect(), size: vec![1; n] }
    }

    fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
        x
    }

    fn union(&mut self, a: u32, b: u32) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return;
        }
        let (big, small) = if self.size[ra as usize] >= self.size[rb as usize] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.parent[small as usize] = big;
        self.size[big as usize] += self.size[small as usize];
    }
}

/// How the shard planner split a DAG, for reports and tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardReport {
    /// Connected components containing at least one task.
    pub components: usize,
    /// Shard simulations actually run (`min(requested, components)`).
    pub shards: usize,
    /// Task count of the largest shard — the wall-clock critical path.
    pub largest_shard_tasks: usize,
}

/// Run a DAG sharded: partition into link-locality components, bucket
/// them into at most `shards` shard [`Sim`]s, and execute the shards on
/// at most `max_workers` pool workers. Merges results back into the
/// original task numbering; see the module docs for the 1e-9 numerical
/// contract. `stats` fields are summed across shards (counter totals are
/// not comparable to an unsharded run of the same DAG) — except
/// [`SimStats::shards_effective`], which is *set* to the shard count
/// that actually ran, so callers can tell a genuine parallel run from a
/// collapsed one. When union-find welds every component into a single
/// bucket the driver short-circuits to the plain engine (bit-exact, no
/// pool dispatch) and reports `shards_effective == 1` instead of
/// masquerading as a sharded run.
pub fn run_sharded(
    sim: Sim<'_>,
    shards: usize,
    max_workers: usize,
) -> (SimResult, SimOutcome, ShardReport) {
    let topo = sim.topology();
    let Sim { mut tasks, cap_events, .. } = sim;
    let n = tasks.len();
    let n_links = topo.links.len();
    if n == 0 {
        let res = SimResult {
            finish: Vec::new(),
            makespan: 0.0,
            linkdir_bytes: vec![0.0; 2 * n_links],
            flows: 0,
            stats: SimStats::default(),
        };
        let report = ShardReport { components: 0, shards: 0, largest_shard_tasks: 0 };
        return (res, SimOutcome::Completed { time: 0.0 }, report);
    }

    // 1. Union tasks along dependency edges and flow—link incidences.
    let mut uf = Uf::new(n + n_links);
    for (i, task) in tasks.iter().enumerate() {
        for &d in &task.dependents {
            uf.union(i as u32, d as u32);
        }
        if let TaskSpec::Flow { linkdirs, .. } = &task.spec {
            for &ld in linkdirs {
                uf.union(i as u32, (n + ld / 2) as u32);
            }
        }
    }

    // 2. Number components in first-task order (deterministic), then
    //    bucket them round-robin into shards.
    const UNSEEN: u32 = u32::MAX;
    let mut comp_of_root = vec![UNSEEN; n + n_links];
    let mut components = 0u32;
    let mut comp_of_task = vec![0u32; n];
    for i in 0..n {
        let r = uf.find(i as u32) as usize;
        if comp_of_root[r] == UNSEEN {
            comp_of_root[r] = components;
            components += 1;
        }
        comp_of_task[i] = comp_of_root[r];
    }
    let num_shards = shards.max(1).min(components as usize).max(1);
    if num_shards == 1 {
        // Silent-collapse fix: one bucket means zero parallelism, so
        // sharding would only pay pool dispatch and then report merged
        // counters indistinguishable from a real multi-shard run. Run
        // the plain engine and say so via `shards_effective`.
        let roots: Vec<usize> = tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.pending_deps == 0)
            .map(|(i, _)| i)
            .collect();
        let plain = Sim { topo, tasks, roots, cap_events };
        let (mut res, out) = plain.run_event_driven();
        res.stats.shards_effective = 1;
        let report =
            ShardReport { components: components as usize, shards: 1, largest_shard_tasks: n };
        return (res, out, report);
    }
    let shard_of_comp = |c: u32| (c as usize) % num_shards;

    // 3. Move tasks into their shards, preserving relative order (so
    //    event tie-breaking inside a shard matches the unsharded order
    //    of its component), and remap dependency edges to local ids.
    let mut local_id = vec![0u32; n];
    let mut shard_tasks: Vec<Vec<Task>> = (0..num_shards).map(|_| Vec::new()).collect();
    let mut global_ids: Vec<Vec<usize>> = (0..num_shards).map(|_| Vec::new()).collect();
    for (i, task) in tasks.iter_mut().enumerate() {
        let s = shard_of_comp(comp_of_task[i]);
        local_id[i] = shard_tasks[s].len() as u32;
        global_ids[s].push(i);
        shard_tasks[s].push(Task {
            spec: std::mem::replace(&mut task.spec, TaskSpec::Delay { secs: 0.0 }),
            pending_deps: task.pending_deps,
            dependents: std::mem::take(&mut task.dependents),
            finish: None,
        });
    }
    for ts in &mut shard_tasks {
        for t in ts.iter_mut() {
            for d in &mut t.dependents {
                // dependents share the component, hence the shard
                *d = local_id[*d] as usize;
            }
        }
    }

    // 4. Capacity steps follow their link's component; links no flow
    //    crosses park on shard 0 (their steps cannot change any rate).
    let mut shard_caps: Vec<Vec<super::engine::CapEvent>> =
        (0..num_shards).map(|_| Vec::new()).collect();
    for e in cap_events {
        let r = uf.find((n + e.link) as u32) as usize;
        let s = if comp_of_root[r] == UNSEEN { 0 } else { shard_of_comp(comp_of_root[r]) };
        shard_caps[s].push(e);
    }

    let largest_shard_tasks = shard_tasks.iter().map(|t| t.len()).max().unwrap_or(0);

    // 5. Fan the shards across pool workers. Each shard calls the
    //    event-driven core *directly*: the reference-engine override is
    //    thread-local and must not silently vanish on worker threads.
    let jobs: Vec<_> = shard_tasks
        .into_iter()
        .zip(shard_caps)
        .map(|(ts, caps)| {
            let roots: Vec<usize> = ts
                .iter()
                .enumerate()
                .filter(|(_, t)| t.pending_deps == 0)
                .map(|(i, _)| i)
                .collect();
            let shard_sim = Sim { topo, tasks: ts, roots, cap_events: caps };
            move || shard_sim.run_event_driven()
        })
        .collect();
    let results = pool::parallel_map_n(max_workers, jobs);

    // 6. Merge. Terminal time is the instant the last shard stopped —
    //    the same instant the unsharded loop would have run dry — and
    //    stuck tasks report it, exactly like the unsharded stall path.
    let mut terminal = 0.0f64;
    let mut all_completed = true;
    for (_, out) in &results {
        terminal = terminal.max(out.time());
        all_completed &= out.is_completed();
    }
    let mut finish = vec![0.0f64; n];
    let mut linkdir_bytes = vec![0.0f64; 2 * n_links];
    let mut flows = 0usize;
    let mut stats = SimStats::default();
    let mut stuck_tasks: Vec<usize> = Vec::new();
    let mut starved_flows = 0usize;
    let mut culprit_links: Vec<usize> = Vec::new();
    for (s, (res, out)) in results.iter().enumerate() {
        for (li, &gi) in global_ids[s].iter().enumerate() {
            finish[gi] = res.finish[li];
        }
        for (acc, &b) in linkdir_bytes.iter_mut().zip(&res.linkdir_bytes) {
            *acc += b;
        }
        flows += res.flows;
        stats.events += res.stats.events;
        stats.completions += res.stats.completions;
        stats.full_refills += res.stats.full_refills;
        stats.refill_flow_visits += res.stats.refill_flow_visits;
        stats.fast_updates += res.stats.fast_updates;
        stats.settlements += res.stats.settlements;
        stats.heap_pushes += res.stats.heap_pushes;
        stats.cap_events += res.stats.cap_events;
        // shards_effective is deliberately NOT summed: each shard ran
        // plain (reports 0), and the merged result must say how many
        // shards genuinely executed — set once below.
        if let SimOutcome::Stalled {
            stuck_tasks: st, starved_flows: sf, culprit_links: cl, ..
        } = out
        {
            stuck_tasks.extend(st.iter().map(|&li| global_ids[s][li]));
            starved_flows += sf;
            culprit_links.extend_from_slice(cl);
        }
    }
    let (outcome, makespan) = if all_completed {
        let makespan = finish.iter().cloned().fold(0.0, f64::max);
        (SimOutcome::Completed { time: makespan }, makespan)
    } else {
        // SimOutcome::stalled owns the sort/dedup contract; sort the
        // local copy too so the finish overwrite below stays in task
        // order (deterministic float folds).
        stuck_tasks.sort_unstable();
        for &gi in &stuck_tasks {
            finish[gi] = terminal; // unsharded semantics: stall instant
        }
        let makespan = finish.iter().cloned().fold(0.0, f64::max);
        (
            SimOutcome::stalled(terminal, stuck_tasks.clone(), starved_flows, culprit_links),
            makespan,
        )
    };
    stats.shards_effective = num_shards as u64;
    let report =
        ShardReport { components: components as usize, shards: num_shards, largest_shard_tasks };
    (SimResult { finish, makespan, linkdir_bytes, flows, stats }, outcome, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::systems::{cluster, dgx1};

    /// Two calls of `build` produce identical DAGs; run one unsharded
    /// and one sharded and compare under the 1e-9 contract.
    fn compare(
        topo: &crate::topology::Topology,
        shards: usize,
        workers: usize,
        build: impl Fn(&mut Sim<'_>),
    ) -> ShardReport {
        let mut a = Sim::new(topo);
        build(&mut a);
        let (ra, oa) = a.run_outcome();
        let mut b = Sim::new(topo);
        build(&mut b);
        let (rb, ob, report) = run_sharded(b, shards, workers);
        assert_eq!(oa.is_completed(), ob.is_completed());
        assert!((oa.time() - ob.time()).abs() <= 1e-9 * oa.time().abs().max(1.0));
        assert_eq!(ra.finish_times().len(), rb.finish_times().len());
        for (x, y) in ra.finish_times().iter().zip(rb.finish_times()) {
            assert!((x - y).abs() < 1e-11 + 1e-9 * y.abs(), "finish {x} vs {y}");
        }
        assert_eq!(ra.flows, rb.flows);
        for (x, y) in ra.linkdir_bytes.iter().zip(&rb.linkdir_bytes) {
            assert!((x - y).abs() <= 1e-6 * y.abs().max(1.0), "bytes {x} vs {y}");
        }
        // plain runs report 0; the sharded driver reports what ran
        assert_eq!(ra.stats.shards_effective, 0);
        assert_eq!(rb.stats.shards_effective, report.shards as u64);
        report
    }

    /// Per-node chains on the cluster star: each node's PCIe hop is its
    /// own component (the shared IB switch links are only crossed by
    /// that node's up-flow in this DAG).
    fn disjoint_chains(sim: &mut Sim<'_>) {
        let t = sim.topology();
        for r in 0..t.num_gpus() {
            let cpu = t.host_cpu(t.gpu(r));
            let p = t.route(t.gpu(r), cpu).unwrap();
            let a = sim.flow(p.clone(), 1e6 * (r + 1) as f64, 1e-6, &[]);
            let b = sim.flow(p.clone(), 5e5, 1e-6, &[a]);
            sim.delay(1e-6, &[b]);
        }
    }

    #[test]
    fn disjoint_components_agree_and_split() {
        let topo = cluster(8);
        for (shards, workers) in [(1, 1), (4, 2), (64, 4)] {
            let report = compare(&topo, shards, workers, disjoint_chains);
            assert_eq!(report.components, 8);
            assert_eq!(report.shards, shards.min(8));
        }
    }

    #[test]
    fn shared_links_merge_components() {
        let topo = dgx1();
        let report = compare(&topo, 8, 4, |sim| {
            let t = sim.topology();
            // rank 0 -> 1 -> 2 chained flows share GPU1's links: one
            // component; rank 4 -> 5 independent: a second component
            let a = sim.flow(t.route_gpus(0, 1).unwrap(), 2e6, 0.0, &[]);
            sim.flow(t.route_gpus(1, 2).unwrap(), 2e6, 0.0, &[a]);
            sim.flow(t.route_gpus(1, 2).unwrap(), 1e6, 0.0, &[]); // contends
            sim.flow(t.route_gpus(4, 5).unwrap(), 3e6, 0.0, &[]);
        });
        assert_eq!(report.components, 2);
        assert_eq!(report.shards, 2);
    }

    #[test]
    fn outage_stall_merges_diagnosis() {
        let topo = cluster(4);
        // node 0's PCIe uplink dies mid-flow; node 1's chain completes
        let dead_link = {
            let p = topo.route(topo.gpu(0), topo.host_cpu(topo.gpu(0))).unwrap();
            p.links[0]
        };
        let build = |sim: &mut Sim<'_>| {
            let t = sim.topology();
            let p0 = t.route(t.gpu(0), t.host_cpu(t.gpu(0))).unwrap();
            let f = sim.flow(p0, 1e9, 0.0, &[]);
            sim.delay(1.0, &[f]); // stuck dependent
            let p1 = t.route(t.gpu(1), t.host_cpu(t.gpu(1))).unwrap();
            sim.flow(p1, 1e6, 0.0, &[]);
            sim.capacity_event(dead_link, 1e-3, 0.0);
        };
        let mut a = Sim::new(&topo);
        build(&mut a);
        let (ra, oa) = a.run_outcome();
        let mut b = Sim::new(&topo);
        build(&mut b);
        let (rb, ob, report) = run_sharded(b, 8, 2);
        assert_eq!(report.components, 2);
        let (SimOutcome::Stalled { time: ta, stuck_tasks: sa, culprit_links: ca, .. },
             SimOutcome::Stalled { time: tb, stuck_tasks: sb, culprit_links: cb, .. }) =
            (&oa, &ob)
        else {
            panic!("expected both stalled: {oa:?} vs {ob:?}");
        };
        assert_eq!(sa, sb);
        assert_eq!(ca, cb);
        assert_eq!(cb, &vec![dead_link]);
        assert!((ta - tb).abs() <= 1e-9 * ta.abs().max(1.0));
        for (x, y) in ra.finish_times().iter().zip(rb.finish_times()) {
            assert!((x - y).abs() < 1e-11 + 1e-9 * y.abs());
        }
    }

    #[test]
    fn single_component_collapse_short_circuits_to_the_plain_engine() {
        // chained flows weld every task into one component: requesting 8
        // shards must degrade to the plain engine, visibly (satellite
        // fix for the silent-collapse bug)
        let topo = dgx1();
        let build = |sim: &mut Sim<'_>| {
            let t = sim.topology();
            let a = sim.flow(t.route_gpus(0, 1).unwrap(), 2e6, 0.0, &[]);
            sim.flow(t.route_gpus(1, 2).unwrap(), 2e6, 0.0, &[a]);
            sim.flow(t.route_gpus(1, 2).unwrap(), 1e6, 0.0, &[]);
        };
        let mut a = Sim::new(&topo);
        build(&mut a);
        let (ra, oa) = a.run_outcome();
        let mut b = Sim::new(&topo);
        build(&mut b);
        let (rb, ob, report) = run_sharded(b, 8, 4);
        assert_eq!(report.components, 1);
        assert_eq!(report.shards, 1);
        assert_eq!(report.largest_shard_tasks, 3);
        assert_eq!(rb.stats.shards_effective, 1, "collapse must be reported, not silent");
        // the short-circuit IS the plain engine: bit-exact, not 1e-9
        assert_eq!(oa.time().to_bits(), ob.time().to_bits());
        for (x, y) in ra.finish_times().iter().zip(rb.finish_times()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in ra.linkdir_bytes.iter().zip(&rb.linkdir_bytes) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(ra.stats.events, rb.stats.events);
    }

    #[test]
    fn empty_dag_is_a_completed_noop() {
        let topo = dgx1();
        let sim = Sim::new(&topo);
        let (res, out, report) = run_sharded(sim, 4, 4);
        assert!(out.is_completed());
        assert_eq!(res.makespan, 0.0);
        assert_eq!(report.components, 0);
    }
}
