//! The pre-rewrite simulation core, kept as a differential-testing
//! oracle for the event-driven engine in [`super::engine`].
//!
//! This is the seed engine exactly as it shipped (minus one dead scratch
//! buffer): it scans **all** active flows at every event to find the
//! next completion, advances byte accounting for every flow at every
//! event, and rebuilds max-min rates from scratch on every start/finish
//! — O(F²·L) for F concurrent flows, which is why it was replaced
//! (DESIGN.md §8). It stays in the tree because:
//!
//! - parity tests (`tests/proptests.rs`, `tests/engine_scaling.rs`)
//!   assert the event-driven engine reproduces its results on random
//!   DAGs and on the paper's own fig2 workloads — the "golden values
//!   before the rewrite" are regenerated on demand instead of pinned as
//!   constants;
//! - `bench_engine` runs both cores on the same DAGs and reports the
//!   speedup (`BENCH_engine.json`), so the ≥3× acceptance bar is
//!   measured, not asserted.
//!
//! Numerical contract: both engines integrate the same piecewise-
//! constant max-min rates, but this one settles byte accounting at every
//! event while the event-driven core settles lazily per rate change.
//! f64 addition is not associative, so results agree to ~1e-9 relative
//! tolerance, not bit-for-bit; each engine is individually bit-exact
//! deterministic across runs.
//!
//! Capacity steps (DESIGN.md §12) are supported here symmetrically to
//! the event engine — a due step rewrites `caps` and forces a
//! from-scratch recompute — so this core stays the differential oracle
//! for the fault subsystem too. The no-op filtering in
//! [`capacity_timeline`] is shared: an empty or zero-magnitude
//! perturbation set introduces no event instants on either core, so
//! both remain bit-exact to their unperturbed runs. (The only textual
//! change to the seed arithmetic: `recompute` became a `fn` taking
//! `caps` as a parameter instead of a closure capturing it, so the main
//! loop can mutate capacities; the progressive-filling arithmetic is
//! untouched.)

use std::collections::BinaryHeap;

use super::engine::{
    capacity_timeline, Event, HeapEntry, LinkDir, Sim, SimOutcome, SimResult, SimStats, TaskSpec,
};
use crate::topology::LinkId;

/// An active flow being rate-controlled. `linkdirs` is moved out of the
/// task spec at activation so the hot loops (rate recomputation, byte
/// accounting) touch a flat, cache-friendly array instead of chasing the
/// task table.
#[derive(Clone, Debug)]
struct ActiveFlow {
    task: usize,
    remaining: f64,
    rate: f64,
    linkdirs: Vec<LinkDir>,
}

impl<'t> Sim<'t> {
    /// Execute the DAG on the pre-rewrite reference core; consumes the
    /// builder. Produces a [`SimResult`] with all-zero
    /// [`SimStats`] (this engine predates the counters). Panics with
    /// the stall diagnosis if the run cannot complete, exactly like
    /// [`Sim::run`].
    pub fn run_reference(self) -> SimResult {
        let (res, outcome) = self.run_reference_outcome();
        if !outcome.is_completed() {
            panic!("simulation deadlock: {}", outcome.describe());
        }
        res
    }

    /// [`Sim::run_reference`] with the terminal [`SimOutcome`] reported
    /// instead of a stall panic — the reference half of the liveness
    /// differential contract: both cores must agree on *whether* a run
    /// stalls, on the stall time (~1e-9 relative) and on the culprit
    /// link set exactly.
    pub fn run_reference_outcome(self) -> (SimResult, SimOutcome) {
        let Sim { topo, mut tasks, roots, cap_events } = self;
        let n_linkdirs = topo.links.len() * 2;
        let mut caps: Vec<f64> = (0..n_linkdirs)
            .map(|ld| topo.links[ld / 2].class.bandwidth())
            .collect();
        // Capacity steps (no-op-filtered, shared with the event engine:
        // an empty/zero-magnitude perturbation set introduces no event
        // instants and stays bit-exact on this core too).
        let cap_timeline = capacity_timeline(topo, &cap_events);
        let mut cap_idx = 0usize;
        let mut linkdir_bytes = vec![0.0; n_linkdirs];

        let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::new();
        let mut seq = 0u64;
        let mut schedule = |heap: &mut BinaryHeap<HeapEntry>, time: f64, event: Event| {
            let s = seq;
            seq += 1;
            heap.push(HeapEntry { time, seq: s, event });
        };

        let mut active: Vec<ActiveFlow> = Vec::new();
        let mut now = 0.0f64;
        let mut flows_total = 0usize;
        let mut completed = 0usize;
        let total = tasks.len();

        // Readiness propagation: when a task becomes ready at time t,
        // schedule its activation/completion event.
        let mut ready_queue: Vec<(usize, f64)> = roots.iter().map(|&r| (r, 0.0)).collect();

        macro_rules! drain_ready {
            () => {
                while let Some((id, t)) = ready_queue.pop() {
                    match tasks[id].spec {
                        TaskSpec::Flow { latency, .. } => {
                            schedule(&mut heap, t + latency, Event::Activate(id));
                        }
                        TaskSpec::Delay { secs } => {
                            schedule(&mut heap, t + secs, Event::DelayDone(id));
                        }
                    }
                }
            };
        }

        // Recompute max-min fair rates via progressive filling. Scratch
        // buffers are hoisted out and reused across calls (§Perf:
        // allocation in this loop dominated grid regeneration). A plain
        // fn rather than a closure so `caps` stays mutable in the main
        // loop for capacity steps — arithmetic is unchanged from the
        // seed engine.
        let mut scratch_cap: Vec<f64> = caps.clone();
        let mut scratch_cnt: Vec<u32> = vec![0; n_linkdirs];
        let mut scratch_unfrozen: Vec<usize> = Vec::new();
        fn recompute(
            active: &mut [ActiveFlow],
            caps: &[f64],
            scratch_cap: &mut [f64],
            scratch_cnt: &mut [u32],
            scratch_unfrozen: &mut Vec<usize>,
        ) {
            if active.is_empty() {
                return;
            }
            scratch_cap.copy_from_slice(caps);
            let remaining_cap = scratch_cap;
            // compact list of still-unfrozen flow indices: each round
            // touches only the flows whose rate is still rising, so the
            // total refill cost is ~ sum over rounds of survivors rather
            // than rounds x all flows (§Perf iteration 2).
            let unfrozen_idx = &mut scratch_unfrozen;
            unfrozen_idx.clear();
            unfrozen_idx.extend(0..active.len());
            for f in active.iter_mut() {
                f.rate = 0.0;
            }
            // per-round counts (the linkdir arrays are tiny — zeroing
            // them wholesale beats touched-set bookkeeping, §Perf iter 3)
            let cnt = &mut scratch_cnt;
            while !unfrozen_idx.is_empty() {
                cnt.iter_mut().for_each(|c| *c = 0);
                for &fi in unfrozen_idx.iter() {
                    for &ld in &active[fi].linkdirs {
                        cnt[ld] += 1;
                    }
                }
                // smallest fair increment across loaded linkdirs
                let mut inc = f64::INFINITY;
                for ld in 0..cnt.len() {
                    if cnt[ld] > 0 {
                        inc = inc.min(remaining_cap[ld] / cnt[ld] as f64);
                    }
                }
                if !inc.is_finite() {
                    for &fi in unfrozen_idx.iter() {
                        active[fi].rate = f64::INFINITY;
                    }
                    break;
                }
                // raise all unfrozen flows by inc, charge links
                for &fi in unfrozen_idx.iter() {
                    active[fi].rate += inc;
                }
                for ld in 0..cnt.len() {
                    remaining_cap[ld] -= inc * cnt[ld] as f64;
                }
                // freeze flows crossing saturated linkdirs
                let eps = 1e-9;
                let before = unfrozen_idx.len();
                unfrozen_idx.retain(|&fi| {
                    let saturated = active[fi]
                        .linkdirs
                        .iter()
                        .any(|&ld| remaining_cap[ld] <= eps * caps[ld]);
                    !saturated
                });
                if unfrozen_idx.len() == before {
                    // Numerical safety: freeze everything at current rates.
                    unfrozen_idx.clear();
                }
            }
        }
        macro_rules! recompute_rates {
            () => {
                recompute(
                    &mut active,
                    &caps,
                    &mut scratch_cap,
                    &mut scratch_cnt,
                    &mut scratch_unfrozen,
                )
            };
        }

        drain_ready!();
        recompute_rates!();

        let mut stalled: Option<SimOutcome> = None;
        while completed < total {
            // Next discrete event vs next flow completion.
            let next_event_t = heap.peek().map(|e| e.time);
            let mut next_flow: Option<(usize, f64)> = None;
            for (fi, f) in active.iter().enumerate() {
                let t = if f.rate > 0.0 {
                    now + f.remaining / f.rate
                } else if f.remaining <= 0.0 {
                    now
                } else {
                    f64::INFINITY
                };
                if next_flow.map(|(_, bt)| t < bt).unwrap_or(true) {
                    next_flow = Some((fi, t));
                }
            }
            let next_cap_t = cap_timeline.get(cap_idx).map(|e| e.0);
            let t_star = [next_event_t, next_flow.map(|(_, tf)| tf), next_cap_t]
                .into_iter()
                .flatten()
                .fold(f64::INFINITY, f64::min);
            if !t_star.is_finite() {
                // Liveness, mirroring the event engine (DESIGN.md §14):
                // every active flow here is frozen at rate zero with
                // bytes remaining, i.e. starved by a zero-capacity link.
                let mut starved_flows = 0usize;
                let mut culprit_links: Vec<LinkId> = Vec::new();
                for f in &active {
                    if f.remaining > 0.0 {
                        starved_flows += 1;
                        culprit_links
                            .extend(f.linkdirs.iter().filter(|&&ld| caps[ld] <= 0.0).map(|&ld| ld / 2));
                    }
                }
                let stuck_tasks: Vec<usize> = tasks
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| t.finish.is_none())
                    .map(|(id, _)| id)
                    .collect();
                stalled = Some(SimOutcome::stalled(now, stuck_tasks, starved_flows, culprit_links));
                break;
            }
            assert!(
                t_star >= now - 1e-12,
                "time went backwards: {t_star} < {now}"
            );
            // Advance all active flows to t_star.
            let dt = (t_star - now).max(0.0);
            if dt > 0.0 {
                for f in active.iter_mut() {
                    let moved = (f.rate * dt).min(f.remaining);
                    f.remaining -= moved;
                    for &ld in &f.linkdirs {
                        linkdir_bytes[ld] += moved;
                    }
                }
            }
            now = t_star;

            let mut topology_changed = false;

            // Complete any flows that drained (tolerate fp dust).
            let mut fi = 0;
            while fi < active.len() {
                if active[fi].remaining <= 1e-6_f64.max(active[fi].rate * 1e-15) {
                    let task_id = active.swap_remove(fi).task;
                    tasks[task_id].finish = Some(now);
                    completed += 1;
                    for di in 0..tasks[task_id].dependents.len() {
                        let dep = tasks[task_id].dependents[di];
                        tasks[dep].pending_deps -= 1;
                        if tasks[dep].pending_deps == 0 {
                            ready_queue.push((dep, now));
                        }
                    }
                    topology_changed = true;
                } else {
                    fi += 1;
                }
            }

            // Apply capacity steps due now (flows were advanced to
            // t_star under the old rates above; the new capacity governs
            // everything from this instant on).
            while let Some(&(t, ld, cap)) = cap_timeline.get(cap_idx) {
                if t > now {
                    break;
                }
                cap_idx += 1;
                caps[ld] = cap;
                topology_changed = true;
            }

            // Fire discrete events at t_star.
            while let Some(e) = heap.peek() {
                if e.time > now + 1e-18 {
                    break;
                }
                let e = heap.pop().unwrap();
                match e.event {
                    Event::Activate(id) => {
                        let TaskSpec::Flow { bytes, .. } = tasks[id].spec else {
                            unreachable!()
                        };
                        if bytes <= 0.0 {
                            tasks[id].finish = Some(now);
                            completed += 1;
                            for di in 0..tasks[id].dependents.len() {
                                let dep = tasks[id].dependents[di];
                                tasks[dep].pending_deps -= 1;
                                if tasks[dep].pending_deps == 0 {
                                    ready_queue.push((dep, now));
                                }
                            }
                        } else {
                            // move the linkdirs out of the spec: the flow
                            // owns them for its active lifetime
                            let linkdirs = match &mut tasks[id].spec {
                                TaskSpec::Flow { linkdirs, .. } => std::mem::take(linkdirs),
                                TaskSpec::Delay { .. } => unreachable!(),
                            };
                            active.push(ActiveFlow {
                                task: id,
                                remaining: bytes,
                                rate: 0.0,
                                linkdirs,
                            });
                            flows_total += 1;
                            topology_changed = true;
                        }
                    }
                    Event::DelayDone(id) => {
                        tasks[id].finish = Some(now);
                        completed += 1;
                        for di in 0..tasks[id].dependents.len() {
                            let dep = tasks[id].dependents[di];
                            tasks[dep].pending_deps -= 1;
                            if tasks[dep].pending_deps == 0 {
                                ready_queue.push((dep, now));
                            }
                        }
                    }
                }
            }

            drain_ready!();
            // Rates only change when the active-flow set (or a link's
            // capacity) changes; skip the O(flows x links) refill
            // otherwise (§Perf).
            if topology_changed {
                recompute_rates!();
            }
        }

        // Stuck tasks (stall path only) report the stall instant; the
        // completed path is bit-identical to the seed engine.
        let finish: Vec<f64> = tasks.iter().map(|t| t.finish.unwrap_or(now)).collect();
        let makespan = finish.iter().cloned().fold(0.0, f64::max);
        let outcome = stalled.unwrap_or(SimOutcome::Completed { time: makespan });
        (
            SimResult {
                finish,
                makespan,
                linkdir_bytes,
                flows: flows_total,
                stats: SimStats::default(),
            },
            outcome,
        )
    }
}
