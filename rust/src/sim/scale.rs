//! Deterministic thousand-rank scale study (DESIGN.md §15).
//!
//! Packages the DAGs and the cross-check arithmetic that
//! `benches/bench_engine.rs`, the CI scale step and
//! `tests/workload_determinism.rs` all share, so the byte-pinned
//! artifact and the timed bench exercise *exactly* the same work:
//!
//! - [`scale_specs`] — the fabrics under study: ≥4096-rank fat-tree and
//!   dragonfly instances (quick mode drops to ~1k ranks for CI smoke);
//! - [`build_leaf_rings`] — the workload shape: one ring-allgather of
//!   chained flows inside every *leaf group* (hosts sharing an edge
//!   switch, a dragonfly router, or a pod node). Leaf-local rings never
//!   cross the fabric core, so every group is an independent
//!   link-locality component — the shape rail-optimized collectives
//!   produce, and the honest best case for the sharded driver;
//! - [`scale_doc`] — simulated metrics only (makespans, component
//!   counts, sharded-vs-unsharded agreement deltas): byte-identical for
//!   a fixed seed, which is what the determinism suite pins. Wall-clock
//!   timings and the shard-count speedup curve are added *on top* by
//!   the bench, never here.

use super::engine::Sim;
use super::sharded::run_sharded;
use crate::topology::systems::SystemSpec;
use crate::topology::Topology;
use crate::util::json::{obj, Json};
use crate::util::prng::Rng;

/// Shard count the deterministic cross-check runs at. Fixed (never
/// derived from the machine's parallelism) so `scale_doc` renders
/// byte-identically everywhere; the bench sweeps *worker* counts
/// against this same plan for its speedup curve.
pub const CROSS_CHECK_SHARDS: usize = 16;

/// The fabrics under scale study. Full mode is the acceptance
/// configuration (≥ 4096 ranks on both families); quick mode is the CI
/// smoke configuration at ~1k ranks.
pub fn scale_specs(quick: bool) -> Vec<SystemSpec> {
    if quick {
        // fat-tree k=16: 1024 hosts; dragonfly (8,4,4): 33 groups x 32 = 1056
        vec![SystemSpec::FatTree { k: 16 }, SystemSpec::Dragonfly { a: 8, p: 4, h: 4 }]
    } else {
        // fat-tree k=26: 4394 hosts; dragonfly (8,8,8): 65 groups x 64 = 4160
        vec![SystemSpec::FatTree { k: 26 }, SystemSpec::Dragonfly { a: 8, p: 8, h: 8 }]
    }
}

/// Ranks per leaf group of a fabric: hosts under one edge switch
/// (fat-tree), one router (dragonfly), one node (pod). Paper systems
/// fall back to a single global group.
pub fn leaf_group_size(spec: SystemSpec) -> usize {
    match spec {
        SystemSpec::Paper(_) => spec.max_gpus(),
        SystemSpec::FatTree { k } => k / 2,
        SystemSpec::Dragonfly { p, .. } => p,
        SystemSpec::MultiPlanePod { gpus, .. } => gpus,
    }
}

/// Build the leaf-local ring workload: inside every group of `group`
/// consecutive ranks, a ring allgather of `group - 1` chained steps
/// (each position's step-s flow depends on its step-(s-1) flow), with
/// seeded per-flow byte jitter so the artifact seed is live. Groups
/// never share links, so the DAG has exactly one link-locality
/// component per (non-singleton) group.
pub fn build_leaf_rings(topo: &Topology, group: usize, seed: u64) -> Sim<'_> {
    let p = topo.num_gpus();
    let group = group.max(1);
    let mut sim = Sim::new(topo);
    let mut rng = Rng::new(seed);
    let ranks: Vec<usize> = (0..p).collect();
    for chunk in ranks.chunks(group) {
        let m = chunk.len();
        if m < 2 {
            continue;
        }
        let mut grng = rng.fork(chunk[0] as u64);
        // prev[i]: position i's flow in the previous step
        let mut prev: Vec<Option<super::TaskId>> = vec![None; m];
        for _step in 0..m - 1 {
            for i in 0..m {
                let (src, dst) = (chunk[i], chunk[(i + 1) % m]);
                let path = topo
                    .route_gpus(src, dst)
                    .unwrap_or_else(|| panic!("no route {src}->{dst}"));
                let lat = topo.path_latency(&path);
                let bytes = 1.0e6 + grng.gen_range(1 << 20) as f64;
                let deps: Vec<_> = prev[i].into_iter().collect();
                prev[i] = Some(sim.flow(path, bytes, lat, &deps));
            }
        }
    }
    sim
}

/// One scale case, cross-checked: the unsharded event engine vs the
/// sharded driver at [`CROSS_CHECK_SHARDS`] shards on the identical
/// DAG. All fields are simulated metrics — deterministic for a fixed
/// seed.
pub struct ScaleCase {
    /// System spec under study.
    pub spec: SystemSpec,
    /// GPU endpoints.
    pub ranks: usize,
    /// Flow tasks in the DAG.
    pub flows: usize,
    /// Link-locality components the shard planner found.
    pub components: usize,
    /// Shard sims actually run.
    pub shards: usize,
    /// Tasks in the largest shard.
    pub largest_shard_tasks: usize,
    /// Sharded makespan (virtual seconds).
    pub makespan: f64,
    /// |sharded − unsharded| / unsharded makespan.
    pub makespan_rel: f64,
    /// max over tasks of |Δfinish| / (1e-11 + 1e-9·|unsharded|),
    /// i.e. the mixed-tolerance margin: < 1.0 means within contract.
    pub finish_margin: f64,
    /// max over linkdirs of |Δbytes| / max(|unsharded|, 1).
    pub bytes_rel: f64,
}

/// Run one spec's case: build the leaf-ring DAG twice, run it
/// unsharded (event core, never the reference) and sharded, and
/// compute the agreement deltas.
pub fn run_case(spec: SystemSpec, seed: u64, workers: usize) -> ScaleCase {
    let topo = spec.build();
    let group = leaf_group_size(spec);
    let ranks = topo.num_gpus();

    let unsharded_sim = build_leaf_rings(&topo, group, seed);
    let flows = unsharded_sim.flow_tasks_since(0);
    let (base, base_out) = unsharded_sim.run_event_driven();
    assert!(base_out.is_completed(), "scale case stalled: {}", base_out.describe());

    let sharded_sim = build_leaf_rings(&topo, group, seed);
    let (shard, shard_out, report) = run_sharded(sharded_sim, CROSS_CHECK_SHARDS, workers);
    assert!(shard_out.is_completed(), "sharded case stalled: {}", shard_out.describe());

    let makespan_rel = (shard.makespan - base.makespan).abs() / base.makespan;
    let mut finish_margin = 0.0f64;
    for (a, b) in shard.finish_times().iter().zip(base.finish_times()) {
        finish_margin = finish_margin.max((a - b).abs() / (1e-11 + 1e-9 * b.abs()));
    }
    let mut bytes_rel = 0.0f64;
    for (a, b) in shard.linkdir_bytes.iter().zip(&base.linkdir_bytes) {
        bytes_rel = bytes_rel.max((a - b).abs() / b.abs().max(1.0));
    }
    ScaleCase {
        spec,
        ranks,
        flows,
        components: report.components,
        shards: report.shards,
        largest_shard_tasks: report.largest_shard_tasks,
        makespan: shard.makespan,
        makespan_rel,
        finish_margin,
        bytes_rel,
    }
}

impl ScaleCase {
    /// Does the sharded run agree with the unsharded engine under the
    /// three-way differential contract (1e-9 relative makespan, mixed
    /// 1e-11 + 1e-9·|t| finishes, 1e-6 relative linkdir bytes)?
    pub fn within_contract(&self) -> bool {
        self.makespan_rel < 1e-9 && self.finish_margin < 1.0 && self.bytes_rel < 1e-6
    }

    /// JSON payload: simulated metrics only (no wall clock).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("system", Json::Str(self.spec.name())),
            ("ranks", Json::Num(self.ranks as f64)),
            ("flows", Json::Num(self.flows as f64)),
            ("components", Json::Num(self.components as f64)),
            ("shards", Json::Num(self.shards as f64)),
            ("largest_shard_tasks", Json::Num(self.largest_shard_tasks as f64)),
            ("makespan_s", Json::Num(self.makespan)),
            ("agree_makespan_rel", Json::Num(self.makespan_rel)),
            ("agree_finish_margin", Json::Num(self.finish_margin)),
            ("agree_bytes_rel", Json::Num(self.bytes_rel)),
        ])
    }
}

/// The deterministic scale-study document: every [`scale_specs`] case
/// run and cross-checked at a fixed shard count. Byte-identical across
/// runs for a fixed `(seed, quick)` — `tests/workload_determinism.rs`
/// pins the quick render — and the base the engine bench embeds its
/// wall-clock speedup curve next to.
pub fn scale_doc(seed: u64, quick: bool) -> Json {
    let cases: Vec<Json> = scale_specs(quick)
        .into_iter()
        .map(|spec| {
            let case = run_case(spec, seed, usize::MAX);
            assert!(
                case.within_contract(),
                "{}: sharded/unsharded disagreement (makespan_rel={}, finish_margin={}, \
                 bytes_rel={})",
                spec.name(),
                case.makespan_rel,
                case.finish_margin,
                case.bytes_rel
            );
            case.to_json()
        })
        .collect();
    obj(vec![
        ("cross_check_shards", Json::Num(CROSS_CHECK_SHARDS as f64)),
        ("quick", Json::Bool(quick)),
        ("scale_cases", Json::Arr(cases)),
        ("seed", Json::Num(seed as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leaf_rings_split_into_per_group_components() {
        // pod: 6 nodes x 4 GPUs -> 6 NVLink-local rings, 6 components
        let spec = SystemSpec::MultiPlanePod { nodes: 6, gpus: 4, rails: 2 };
        let case = run_case(spec, 7, 4);
        assert_eq!(case.ranks, 24);
        assert_eq!(case.components, 6);
        assert_eq!(case.shards, 6); // capped by components
        assert_eq!(case.flows, 6 * 4 * 3);
        assert!(case.within_contract(), "margin {}", case.finish_margin);
    }

    #[test]
    fn small_fat_tree_case_agrees() {
        let case = run_case(SystemSpec::FatTree { k: 4 }, 11, 2);
        // k=4: 8 edge switches x 2 hosts -> 8 groups of 2
        assert_eq!(case.ranks, 16);
        assert_eq!(case.components, 8);
        assert_eq!(case.flows, 8 * 2);
        assert!(case.within_contract());
    }

    #[test]
    fn scale_doc_seed_is_live() {
        // tiny stand-in via run_case (the full quick doc is pinned by
        // tests/workload_determinism.rs): byte jitter must track the seed
        let a = run_case(SystemSpec::FatTree { k: 4 }, 1, 2);
        let b = run_case(SystemSpec::FatTree { k: 4 }, 2, 2);
        assert_ne!(a.makespan.to_bits(), b.makespan.to_bits());
    }
}
