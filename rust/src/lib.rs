//! agv-bench: reproduction of "An Empirical Evaluation of Allgatherv on
//! Multi-GPU Systems" (Rolinger, Simon, Krieger — CCGRID 2018).
//!
//! The crate provides, per DESIGN.md:
//! - [`topology`]: the paper's three multi-GPU systems (Fig. 1);
//! - [`sim`]: a deterministic discrete-event flow simulator with max-min
//!   fair link sharing;
//! - [`comm`]: MPI / CUDA-aware MVAPICH / NCCL Allgatherv models (§II);
//! - [`osu`]: the OSU Allgatherv micro-benchmark port (Fig. 2);
//! - [`tensor`]: the Table I data sets and the DFacTo partitioner;
//! - [`cpals`]: ReFacTo — communication study (Fig. 3) and the end-to-end
//!   factorization driver over the PJRT runtime;
//! - [`runtime`]: AOT HLO-text loading + execution (xla/PJRT);
//! - [`report`]: renderers regenerating every paper table and figure;
//! - [`workload`]: multi-tenant engine — N concurrent Allgatherv jobs
//!   composed into one shared simulation (contended latency study);
//! - [`perturb`]: fault & variability subsystem — degraded links,
//!   straggler GPUs, time-varying bandwidth, Monte-Carlo ensembles
//!   (the `agv faults` study and the robust selector);
//! - [`util`]: self-contained PRNG / stats / bench / prop-test / CLI.
#![warn(missing_docs)]

pub mod comm;
pub mod cpals;
pub mod osu;
pub mod perturb;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod topology;
pub mod util;
pub mod workload;
