//! Materialized synthetic tensors for the end-to-end workloads.
//!
//! Coordinates are drawn from the same power-law profiles as the
//! paper-scale data sets (inverse-CDF sampling per mode); values can be
//! pure noise or planted low-rank structure (so CP-ALS has something to
//! converge to and the e2e fit curve is meaningful).

use crate::util::prng::Rng;

use super::{CooTensor, TensorSpec};

/// Inverse-CDF sample of the power-law profile: u ~ U[0,1) ->
/// floor(dim * u^(1/(1-s))).
fn sample_index(rng: &mut Rng, dim: u64, skew: f64) -> u32 {
    let u = rng.next_f64();
    let x = (dim as f64 * u.powf(1.0 / (1.0 - skew))) as u64;
    x.min(dim - 1) as u32
}

/// Draw `nnz` coordinates with iid per-mode profiles and N(0,1) values.
pub fn random_coo(spec: &TensorSpec, nnz: usize, seed: u64) -> CooTensor {
    let mut rng = Rng::new(seed);
    let dims = spec.dims();
    let mut t = CooTensor {
        dims,
        i: Vec::with_capacity(nnz),
        j: Vec::with_capacity(nnz),
        k: Vec::with_capacity(nnz),
        vals: Vec::with_capacity(nnz),
    };
    for _ in 0..nnz {
        t.i.push(sample_index(&mut rng, dims[0], spec.modes[0].skew));
        t.j.push(sample_index(&mut rng, dims[1], spec.modes[1].skew));
        t.k.push(sample_index(&mut rng, dims[2], spec.modes[2].skew));
        t.vals.push(rng.normal() as f32);
    }
    t
}

/// Plant a rank-`true_rank` low-rank signal: coordinates as in
/// [`random_coo`], values = sum_r a_i b_j c_k + noise_scale * N(0,1).
pub fn low_rank_coo(
    spec: &TensorSpec,
    nnz: usize,
    true_rank: usize,
    noise_scale: f32,
    seed: u64,
) -> CooTensor {
    let mut rng = Rng::new(seed);
    let dims = spec.dims();
    let factor = |rng: &mut Rng, d: u64| -> Vec<f32> {
        (0..d as usize * true_rank).map(|_| rng.normal() as f32 * 0.5).collect()
    };
    let fa = factor(&mut rng, dims[0]);
    let fb = factor(&mut rng, dims[1]);
    let fc = factor(&mut rng, dims[2]);
    let mut t = random_coo(spec, nnz, seed ^ 0xD00D);
    for n in 0..nnz {
        let (i, j, k) = (t.i[n] as usize, t.j[n] as usize, t.k[n] as usize);
        let mut v = 0.0f32;
        for r in 0..true_rank {
            v += fa[i * true_rank + r] * fb[j * true_rank + r] * fc[k * true_rank + r];
        }
        t.vals[n] = v + noise_scale * rng.normal() as f32;
    }
    t
}

/// Pad a COO tensor to `n_pad` entries with (val=0, idx=0) so its shape
/// matches an AOT artifact (the model treats zero-valued entries as
/// no-ops). Panics if the tensor is larger than the padded size.
pub fn pad_coo(t: &CooTensor, n_pad: usize) -> CooTensor {
    assert!(t.nnz() <= n_pad, "tensor ({}) larger than pad ({n_pad})", t.nnz());
    let mut out = t.clone();
    out.i.resize(n_pad, 0);
    out.j.resize(n_pad, 0);
    out.k.resize(n_pad, 0);
    out.vals.resize(n_pad, 0.0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ModeProfile;

    fn small_spec() -> TensorSpec {
        TensorSpec {
            name: "small",
            modes: [
                ModeProfile { dim: 128, skew: 0.6 },
                ModeProfile { dim: 64, skew: 0.3 },
                ModeProfile { dim: 64, skew: 0.0 },
            ],
            nnz: 2048,
        }
    }

    #[test]
    fn random_coo_in_bounds() {
        let t = random_coo(&small_spec(), 2048, 7);
        assert_eq!(t.nnz(), 2048);
        assert!(t.i.iter().all(|&x| (x as u64) < 128));
        assert!(t.j.iter().all(|&x| (x as u64) < 64));
        assert!(t.k.iter().all(|&x| (x as u64) < 64));
    }

    #[test]
    fn deterministic_by_seed() {
        let a = random_coo(&small_spec(), 512, 42);
        let b = random_coo(&small_spec(), 512, 42);
        assert_eq!(a.i, b.i);
        assert_eq!(a.vals, b.vals);
        let c = random_coo(&small_spec(), 512, 43);
        assert_ne!(a.i, c.i);
    }

    #[test]
    fn skew_concentrates_head() {
        let t = random_coo(&small_spec(), 8192, 3);
        let h = t.mode_histogram(0);
        let head: u64 = h[..16].iter().sum();
        let tail: u64 = h[112..].iter().sum();
        assert!(head > 4 * tail, "head={head} tail={tail}");
        // mode 2 is uniform: no such concentration
        let h2 = t.mode_histogram(2);
        let head2: u64 = h2[..8].iter().sum();
        let tail2: u64 = h2[56..].iter().sum();
        assert!(head2 < 3 * tail2.max(1), "head2={head2} tail2={tail2}");
    }

    #[test]
    fn low_rank_has_structure() {
        // planted low-rank values should have larger magnitude than noise
        let t = low_rank_coo(&small_spec(), 4096, 4, 0.01, 11);
        let energy: f64 = t.norm_sq() / t.nnz() as f64;
        assert!(energy > 0.05, "energy {energy}");
    }

    #[test]
    fn pad_extends_with_zeros() {
        let t = random_coo(&small_spec(), 100, 5);
        let p = pad_coo(&t, 256);
        assert_eq!(p.nnz(), 256);
        assert_eq!(p.vals[100..], vec![0.0; 156][..]);
        assert_eq!(&p.vals[..100], &t.vals[..]);
    }

    #[test]
    #[should_panic(expected = "larger than pad")]
    fn pad_rejects_shrink() {
        let t = random_coo(&small_spec(), 100, 5);
        let _ = pad_coo(&t, 50);
    }
}
