//! DFacTo/ReFacTo coarse-grained partitioning (paper §III-A): each rank
//! owns a *contiguous* slice of every mode, chosen to balance nonzeros.
//! The slice widths (row counts) are exactly the Allgatherv message sizes
//! (x R x 4 bytes), so this module is the bridge from data-set shape to
//! communication irregularity.

use super::ModeProfile;

/// Slice boundaries from the analytic power-law density profile:
/// density(t) ~ t^-s on (0, dim], so the nnz CDF is F(x) = (x/dim)^(1-s)
/// and the k-th boundary is dim * (k/P)^(1/(1-s)). Returns P+1 indices,
/// first 0 and last `dim`, each slice non-empty where dim >= P.
pub fn profile_boundaries(mode: &ModeProfile, parts: usize) -> Vec<u64> {
    assert!(parts >= 1);
    assert!(
        (0.0..1.0).contains(&mode.skew),
        "skew must be in [0,1), got {}",
        mode.skew
    );
    let d = mode.dim as f64;
    let inv = 1.0 / (1.0 - mode.skew);
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(0u64);
    for k in 1..parts {
        let frac = (k as f64 / parts as f64).powf(inv);
        let mut x = (d * frac).round() as u64;
        // keep slices non-empty and monotone
        let prev = *bounds.last().unwrap();
        if x <= prev {
            x = prev + 1;
        }
        x = x.min(mode.dim - (parts - k) as u64);
        bounds.push(x);
    }
    bounds.push(mode.dim);
    bounds
}

/// Rows per rank from the analytic profile.
pub fn profile_rows(mode: &ModeProfile, parts: usize) -> Vec<u64> {
    let b = profile_boundaries(mode, parts);
    b.windows(2).map(|w| w[1] - w[0]).collect()
}

/// Exact nnz-balanced contiguous partition of a materialized histogram:
/// greedy sweep placing boundaries at the nnz quantiles.
pub fn histogram_boundaries(hist: &[u64], parts: usize) -> Vec<u64> {
    assert!(parts >= 1);
    let total: u64 = hist.iter().sum();
    let dim = hist.len() as u64;
    let mut bounds = vec![0u64];
    let mut acc = 0u64;
    let mut next_quota = 1u64;
    for (i, &h) in hist.iter().enumerate() {
        acc += h;
        while next_quota < parts as u64
            && acc * parts as u64 >= total * next_quota
        {
            let mut x = (i + 1) as u64;
            let prev = *bounds.last().unwrap();
            if x <= prev {
                x = prev + 1;
            }
            x = x.min(dim - (parts as u64 - next_quota));
            bounds.push(x);
            next_quota += 1;
        }
    }
    while bounds.len() < parts {
        let prev = *bounds.last().unwrap();
        bounds.push((prev + 1).min(dim - 1));
    }
    bounds.push(dim);
    bounds
}

/// Rows per rank for an exact histogram.
pub fn histogram_rows(hist: &[u64], parts: usize) -> Vec<u64> {
    let b = histogram_boundaries(hist, parts);
    b.windows(2).map(|w| w[1] - w[0]).collect()
}

/// Nonzeros per rank implied by the analytic profile (for load-balance
/// verification): integrate the density over each slice.
pub fn profile_nnz_share(mode: &ModeProfile, parts: usize, nnz: u64) -> Vec<u64> {
    let b = profile_boundaries(mode, parts);
    let d = mode.dim as f64;
    let e = 1.0 - mode.skew;
    let cdf = |x: u64| (x as f64 / d).powf(e);
    b.windows(2)
        .map(|w| ((cdf(w[1]) - cdf(w[0])) * nnz as f64).round() as u64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prng::Rng;
    use crate::util::prop::check;

    #[test]
    fn uniform_profile_splits_evenly() {
        let m = ModeProfile { dim: 1000, skew: 0.0 };
        let rows = profile_rows(&m, 4);
        assert_eq!(rows, vec![250, 250, 250, 250]);
    }

    #[test]
    fn skewed_profile_front_slices_are_narrow() {
        let m = ModeProfile { dim: 480_000, skew: 0.65 };
        let rows = profile_rows(&m, 2);
        // the dense head slice is much narrower
        assert!(rows[0] < rows[1] / 4, "{rows:?}");
        assert_eq!(rows.iter().sum::<u64>(), 480_000);
        // calibration anchor: ~66K/414K (NETFLIX mode-0, Table I's 26.5MB)
        assert!((60_000..75_000).contains(&rows[0]), "{rows:?}");
    }

    #[test]
    fn boundaries_are_monotone_and_complete() {
        for parts in [1usize, 2, 3, 8, 16] {
            for skew in [0.0, 0.3, 0.86, 0.95] {
                let m = ModeProfile { dim: 10_000, skew };
                let b = profile_boundaries(&m, parts);
                assert_eq!(b.len(), parts + 1);
                assert_eq!(b[0], 0);
                assert_eq!(*b.last().unwrap(), 10_000);
                for w in b.windows(2) {
                    assert!(w[1] > w[0], "parts={parts} skew={skew} {b:?}");
                }
            }
        }
    }

    #[test]
    fn tiny_dim_still_nonempty_slices() {
        let m = ModeProfile { dim: 16, skew: 0.9 };
        let rows = profile_rows(&m, 16);
        assert!(rows.iter().all(|&r| r >= 1), "{rows:?}");
        assert_eq!(rows.iter().sum::<u64>(), 16);
    }

    #[test]
    fn histogram_partition_balances_nnz() {
        let mut rng = Rng::new(1);
        let hist: Vec<u64> = (0..1000).map(|_| rng.gen_range(100)).collect();
        let total: u64 = hist.iter().sum();
        let parts = 8;
        let b = histogram_boundaries(&hist, parts);
        let shares: Vec<u64> = b
            .windows(2)
            .map(|w| hist[w[0] as usize..w[1] as usize].iter().sum())
            .collect();
        let target = total / parts as u64;
        for s in &shares {
            // contiguous greedy can't be perfect; bounded imbalance
            assert!(
                (*s as i64 - target as i64).unsigned_abs() < target,
                "share {s} vs target {target}"
            );
        }
    }

    #[test]
    fn profile_nnz_share_is_balanced() {
        let m = ModeProfile { dim: 1_000_000, skew: 0.7 };
        let shares = profile_nnz_share(&m, 8, 100_000_000);
        let target = 100_000_000 / 8;
        for s in &shares {
            let rel = (*s as f64 - target as f64).abs() / target as f64;
            assert!(rel < 0.05, "share {s} vs {target}");
        }
    }

    #[test]
    fn prop_histogram_boundaries_valid() {
        check("hist-bounds", 64, |rng| {
            let dim = 16 + rng.gen_range(2000) as usize;
            let parts = 1 + rng.gen_range(16) as usize;
            if dim < parts {
                return Ok(());
            }
            let hist: Vec<u64> = (0..dim).map(|_| rng.gen_range(50)).collect();
            let b = histogram_boundaries(&hist, parts);
            prop_assert!(b.len() == parts + 1, "len {}", b.len());
            prop_assert!(b[0] == 0 && *b.last().unwrap() == dim as u64);
            for w in b.windows(2) {
                prop_assert!(w[1] > w[0], "non-monotone {b:?}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_profile_rows_partition_dim() {
        check("profile-rows", 64, |rng| {
            let dim = 64 + rng.gen_range(1_000_000);
            let parts = 1 + rng.gen_range(16) as usize;
            let skew = rng.gen_f64(0.0, 0.99);
            let rows = profile_rows(&ModeProfile { dim, skew }, parts);
            prop_assert!(rows.iter().sum::<u64>() == dim);
            prop_assert!(rows.iter().all(|&r| r >= 1));
            Ok(())
        });
    }
}
