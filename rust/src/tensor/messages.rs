//! Allgatherv message traces and Table I statistics.
//!
//! During one CP-ALS iteration ReFacTo performs one Allgatherv per mode;
//! rank r contributes rows(r, mode) x R x 4 bytes. The message population
//! of a factorization is therefore {rows(r, m) x 64 : r in ranks, m in
//! modes} (identical every iteration). Table I reports avg / min / max /
//! CV of exactly this population at 2 and 8 GPUs.

use crate::util::stats::Summary;

use super::datasets::ROW_BYTES;
use super::partition::profile_rows;
use super::TensorSpec;

/// Per-mode per-rank Allgatherv counts (bytes) for a data set at P ranks.
pub fn mode_counts(spec: &TensorSpec, parts: usize) -> [Vec<u64>; 3] {
    let mk = |m| {
        profile_rows(&spec.modes[m], parts)
            .into_iter()
            .map(|rows| rows * ROW_BYTES)
            .collect::<Vec<u64>>()
    };
    [mk(0), mk(1), mk(2)]
}

/// All messages sent by all ranks in one iteration (bytes, f64 for stats).
pub fn message_trace(spec: &TensorSpec, parts: usize) -> Vec<f64> {
    mode_counts(spec, parts)
        .iter()
        .flat_map(|c| c.iter().map(|&b| b as f64))
        .collect()
}

/// One Table I row at a given GPU count.
#[derive(Clone, Debug)]
pub struct MsgStats {
    /// GPU (rank) count of this table row.
    pub gpus: usize,
    /// Statistics over all per-rank per-mode message sizes (bytes).
    pub summary: Summary,
}

impl MsgStats {
    /// Message statistics for a data set at a given GPU count.
    pub fn of(spec: &TensorSpec, gpus: usize) -> MsgStats {
        MsgStats { gpus, summary: Summary::of(&message_trace(spec, gpus)) }
    }

    /// Mean message size in MB (Table I "Avg").
    pub fn avg_mb(&self) -> f64 {
        self.summary.mean / (1 << 20) as f64
    }

    /// Smallest message in MB (Table I "Min").
    pub fn min_mb(&self) -> f64 {
        self.summary.min / (1 << 20) as f64
    }

    /// Largest message in MB (Table I "Max").
    pub fn max_mb(&self) -> f64 {
        self.summary.max / (1 << 20) as f64
    }

    /// Coefficient of variation (Table I's irregularity measure).
    pub fn cv(&self) -> f64 {
        self.summary.cv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::datasets;

    /// Table I calibration: our analytic profiles must land in the same
    /// regime as the paper's measurements (shape, not absolutes —
    /// tolerances are generous because the paper's rank R is unstated).
    #[test]
    fn netflix_table1_2gpus() {
        let s = MsgStats::of(&datasets::netflix(), 2);
        assert!((4.0..9.0).contains(&s.avg_mb()), "avg {}", s.avg_mb());
        assert!((0.02..0.08).contains(&s.min_mb()), "min {}", s.min_mb());
        assert!((20.0..33.0).contains(&s.max_mb()), "max {}", s.max_mb());
        assert!((1.1..2.2).contains(&s.cv()), "cv {}", s.cv());
    }

    #[test]
    fn amazon_table1_2gpus() {
        let s = MsgStats::of(&datasets::amazon(), 2);
        assert!((40.0..90.0).contains(&s.avg_mb()), "avg {}", s.avg_mb());
        assert!(s.cv() < 0.7, "cv {}", s.cv());
        assert!(s.summary.spread() < 10.0, "spread {}", s.summary.spread());
    }

    #[test]
    fn delicious_table1_2gpus() {
        let s = MsgStats::of(&datasets::delicious(), 2);
        assert!((0.1..0.4).contains(&s.min_mb()), "min {}", s.min_mb());
        assert!(s.max_mb() > 400.0, "max {}", s.max_mb());
        // the paper's headline: >2,000x spread within one data set
        assert!(s.summary.spread() > 1000.0, "spread {}", s.summary.spread());
        assert!((1.0..1.8).contains(&s.cv()), "cv {}", s.cv());
    }

    #[test]
    fn nell1_table1_2gpus() {
        let s = MsgStats::of(&datasets::nell1(), 2);
        assert!((50.0..80.0).contains(&s.min_mb()), "min {}", s.min_mb());
        assert!((600.0..1000.0).contains(&s.max_mb()), "max {}", s.max_mb());
        assert!((0.8..1.4).contains(&s.cv()), "cv {}", s.cv());
    }

    #[test]
    fn cv_roughly_stable_in_gpu_count() {
        // Table I: CVs barely move between 2 and 8 GPUs (0.44/0.44,
        // 1.06/1.06, 1.35->1.48, 1.5->1.84)
        for d in datasets::all() {
            let c2 = MsgStats::of(&d, 2).cv();
            let c8 = MsgStats::of(&d, 8).cv();
            assert!(
                (c8 - c2).abs() < 0.75,
                "{}: cv2={c2} cv8={c8}",
                d.name
            );
        }
    }

    #[test]
    fn eight_gpus_smaller_messages() {
        for d in datasets::all() {
            let s2 = MsgStats::of(&d, 2);
            let s8 = MsgStats::of(&d, 8);
            assert!(s8.avg_mb() < s2.avg_mb(), "{}", d.name);
            assert!(s8.max_mb() < s2.max_mb(), "{}", d.name);
        }
    }

    #[test]
    fn trace_length_is_3p() {
        let t = message_trace(&datasets::netflix(), 8);
        assert_eq!(t.len(), 24);
    }

    #[test]
    fn counts_total_matches_dims() {
        // per mode, sum of per-rank bytes == dim x ROW_BYTES
        let d = datasets::delicious();
        let counts = mode_counts(&d, 16);
        for (m, c) in counts.iter().enumerate() {
            let total: u64 = c.iter().sum();
            assert_eq!(total, d.modes[m].dim * ROW_BYTES, "mode {m}");
        }
    }
}
