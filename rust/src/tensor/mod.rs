//! Sparse tensor substrate for the ReFacTo case study (paper §III,
//! Table I).
//!
//! ReFacTo's communication volume is fully determined by the *per-mode
//! nonzero distributions*: DFacTo assigns each rank a contiguous slice of
//! every mode, balancing nonzeros, and each rank's Allgatherv message for
//! a mode is (rows in its slice) x R x 4 bytes. We therefore model each
//! data set as per-mode fiber-density profiles (power-law over index
//! order, per-mode exponent), calibrated in [`datasets`] so the resulting
//! message statistics reproduce Table I; coordinates only need to be
//! materialized for the small end-to-end tensors ([`synth`]).

pub mod datasets;
pub mod messages;
pub mod partition;
pub mod synth;

/// Power-law fiber-density profile along one mode: density(i) ~ (i+1)^-s
/// over index order. `skew = 0` is uniform; larger values concentrate
/// nonzeros in a small index prefix (what makes DFacTo's nnz-balanced
/// slices so uneven in rows, and hence the messages so irregular).
#[derive(Clone, Copy, Debug)]
pub struct ModeProfile {
    /// Number of indices (rows) along this mode.
    pub dim: u64,
    /// Power-law skew exponent in [0, 1): 0 is uniform.
    pub skew: f64,
}

/// A (3-mode) sparse tensor described by its mode profiles — enough to
/// derive every communication quantity in the paper.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    /// Data-set name as printed in Table I.
    pub name: &'static str,
    /// Per-mode density profiles.
    pub modes: [ModeProfile; 3],
    /// Number of nonzeros.
    pub nnz: u64,
}

impl TensorSpec {
    /// The three mode dimensions.
    pub fn dims(&self) -> [u64; 3] {
        [self.modes[0].dim, self.modes[1].dim, self.modes[2].dim]
    }
}

/// A materialized sparse tensor in COO format (only used for the small
/// end-to-end workloads; the paper-scale data sets never materialize).
#[derive(Clone, Debug)]
pub struct CooTensor {
    /// Mode dimensions.
    pub dims: [u64; 3],
    /// Mode-0 coordinates, one per nonzero.
    pub i: Vec<u32>,
    /// Mode-1 coordinates.
    pub j: Vec<u32>,
    /// Mode-2 coordinates.
    pub k: Vec<u32>,
    /// Nonzero values.
    pub vals: Vec<f32>,
}

impl CooTensor {
    /// Number of stored entries (including any zero padding).
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Squared Frobenius norm of the stored values.
    pub fn norm_sq(&self) -> f64 {
        self.vals.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Histogram of nonzeros along one mode (for exact partitioning).
    pub fn mode_histogram(&self, mode: usize) -> Vec<u64> {
        let (idx, dim) = match mode {
            0 => (&self.i, self.dims[0]),
            1 => (&self.j, self.dims[1]),
            2 => (&self.k, self.dims[2]),
            _ => panic!("mode out of range"),
        };
        let mut h = vec![0u64; dim as usize];
        for &x in idx.iter() {
            h[x as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coo_histogram_counts() {
        let t = CooTensor {
            dims: [4, 2, 2],
            i: vec![0, 0, 3, 1],
            j: vec![0, 1, 1, 0],
            k: vec![0, 0, 1, 1],
            vals: vec![1.0, 2.0, 3.0, 4.0],
        };
        assert_eq!(t.mode_histogram(0), vec![2, 1, 0, 1]);
        assert_eq!(t.mode_histogram(1), vec![2, 2]);
        assert_eq!(t.nnz(), 4);
        assert!((t.norm_sq() - 30.0).abs() < 1e-12);
    }
}
