//! The paper's four real-world data sets (Table I), modeled as calibrated
//! per-mode power-law profiles.
//!
//! Dimensions and nonzero counts are the paper's exactly; the per-mode
//! skew exponents are calibrated so the DFacTo partition reproduces
//! Table I's message statistics (avg / min / max / CV at 2 and 8 GPUs)
//! at rank R = 16, single precision:
//!
//! - NETFLIX mode 0 (480K users, skew 0.65) yields the 66K/414K row split
//!   behind the paper's 26.5 MB max / 2-GPU message;
//! - DELICIOUS mode 0 (532K, skew 0.86) produces the 0.2 MB minimum and,
//!   with the 17M mode, the >2000x min/max spread;
//! - AMAZON is the mild one (CV 0.44);
//! - NELL-1 is dominated by its 25M-row mode (729 MB-class messages) with
//!   mild within-mode skew (CV ~ 1.06).
//!
//! The paper's exact rank is unstated and its per-data-set averages imply
//! slightly different R per set; we fix R = 16 and reproduce the *shape*
//! (ordering, spreads, CVs) — see EXPERIMENTS.md for measured-vs-paper.

use super::{ModeProfile, TensorSpec};

/// Rank of the decomposition used throughout (single precision).
pub const RANK: usize = 16;
/// Bytes per factor row communicated: R x f32.
pub const ROW_BYTES: u64 = (RANK * 4) as u64;

/// NETFLIX: 480K x 18K x 2K, 100M nonzeros (Table I row 1).
pub fn netflix() -> TensorSpec {
    TensorSpec {
        name: "NETFLIX",
        modes: [
            ModeProfile { dim: 480_000, skew: 0.65 },
            ModeProfile { dim: 18_000, skew: 0.50 },
            ModeProfile { dim: 2_000, skew: 0.40 },
        ],
        nnz: 100_000_000,
    }
}

/// AMAZON: 524K x 2M x 2M, 200M nonzeros — the regular one (CV 0.44).
pub fn amazon() -> TensorSpec {
    TensorSpec {
        name: "AMAZON",
        modes: [
            ModeProfile { dim: 524_000, skew: 0.30 },
            ModeProfile { dim: 2_000_000, skew: 0.25 },
            ModeProfile { dim: 2_000_000, skew: 0.25 },
        ],
        // paper: modified to 200M of the original 1.7B nonzeros
        nnz: 200_000_000,
    }
}

/// DELICIOUS: 532K x 17M x 2M, 140M nonzeros — the >2000x-spread one.
pub fn delicious() -> TensorSpec {
    TensorSpec {
        name: "DELICIOUS",
        modes: [
            ModeProfile { dim: 532_000, skew: 0.86 },
            ModeProfile { dim: 17_000_000, skew: 0.35 },
            ModeProfile { dim: 2_000_000, skew: 0.60 },
        ],
        nnz: 140_000_000,
    }
}

/// NELL-1: 3M x 2M x 25M, 143M nonzeros — 729 MB-class max messages.
pub fn nell1() -> TensorSpec {
    TensorSpec {
        name: "NELL-1",
        modes: [
            ModeProfile { dim: 3_000_000, skew: 0.15 },
            ModeProfile { dim: 2_000_000, skew: 0.10 },
            ModeProfile { dim: 25_000_000, skew: 0.15 },
        ],
        nnz: 143_000_000,
    }
}

/// Table I order: ascending average message size.
pub fn all() -> Vec<TensorSpec> {
    vec![netflix(), amazon(), delicious(), nell1()]
}

/// Case-insensitive data-set lookup ("nell1" and "NELL-1" both work).
pub fn by_name(name: &str) -> Option<TensorSpec> {
    all()
        .into_iter()
        .find(|d| d.name.eq_ignore_ascii_case(name) || d.name.replace('-', "").eq_ignore_ascii_case(&name.replace('-', "")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_dimensions() {
        let n = netflix();
        assert_eq!(n.dims(), [480_000, 18_000, 2_000]);
        assert_eq!(n.nnz, 100_000_000);
        let d = delicious();
        assert_eq!(d.dims(), [532_000, 17_000_000, 2_000_000]);
        let l = nell1();
        assert_eq!(l.dims(), [3_000_000, 2_000_000, 25_000_000]);
        assert_eq!(amazon().nnz, 200_000_000);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("netflix").unwrap().name, "NETFLIX");
        assert_eq!(by_name("NELL-1").unwrap().name, "NELL-1");
        assert_eq!(by_name("nell1").unwrap().name, "NELL-1");
        assert!(by_name("unknown").is_none());
    }

    #[test]
    fn table_order_is_ascending_avg() {
        use crate::tensor::messages::message_trace;
        let avgs: Vec<f64> = all()
            .iter()
            .map(|d| {
                let t = message_trace(d, 2);
                t.iter().sum::<f64>() / t.len() as f64
            })
            .collect();
        for w in avgs.windows(2) {
            assert!(w[1] > w[0], "not ascending: {avgs:?}");
        }
    }
}
