//! Fig. 3: ReFacTo total communication time.
//!
//! Per CP-ALS iteration ReFacTo issues one Allgatherv per mode with the
//! DFacTo partition's per-rank counts; the counts are identical across
//! iterations (the partition is static), so total communication time is
//! `iters x sum_over_modes(allgatherv(mode counts))`. The paper measures
//! "the time required to perform all of the GPU communication during the
//! tensor factorization, including HtoD/DtoH transfers when applicable" —
//! the library models already include those.

use crate::comm::select::{AlgoSelector, Selection};
use crate::comm::{Library, Params};
use crate::tensor::messages::mode_counts;
use crate::tensor::TensorSpec;
use crate::topology::Topology;

/// Default iteration count for the factorization experiments.
pub const DEFAULT_ITERS: usize = 10;

/// One (data set, system, library, GPU count) cell of Fig. 3.
#[derive(Clone, Debug)]
pub struct RefactoReport {
    /// Data-set name (Table I).
    pub dataset: &'static str,
    /// Library that ran the collectives.
    pub library: Library,
    /// Simulated GPU (rank) count.
    pub gpus: usize,
    /// CP-ALS iterations the total covers.
    pub iters: usize,
    /// total communication time over the whole factorization (seconds)
    pub total_time: f64,
    /// per-mode single-iteration Allgatherv times
    pub per_mode: [f64; 3],
    /// flows simulated (one iteration)
    pub flows: usize,
}

/// Simulate ReFacTo's communication for one configuration.
pub fn refacto_comm(
    topo: &Topology,
    lib: Library,
    params: Params,
    spec: &TensorSpec,
    gpus: usize,
    iters: usize,
) -> RefactoReport {
    assert!(gpus >= 1 && gpus <= topo.num_gpus());
    let library = lib.build(params);
    let counts = mode_counts(spec, gpus);
    let mut per_mode = [0.0f64; 3];
    let mut flows = 0usize;
    for (m, c) in counts.iter().enumerate() {
        let r = library.allgatherv(topo, c);
        per_mode[m] = r.time;
        flows += r.flows;
    }
    let once: f64 = per_mode.iter().sum();
    RefactoReport {
        dataset: spec.name,
        library: lib,
        gpus,
        iters,
        total_time: once * iters as f64,
        per_mode,
        flows,
    }
}

/// The `auto` counterpart of [`RefactoReport`]: per mode, the
/// selector's winning (library, algorithm) pair and its time.
#[derive(Clone, Debug)]
pub struct AutoRefactoReport {
    /// Data-set name (Table I).
    pub dataset: &'static str,
    /// Simulated GPU (rank) count.
    pub gpus: usize,
    /// CP-ALS iterations the total covers.
    pub iters: usize,
    /// total communication time over the whole factorization (seconds)
    pub total_time: f64,
    /// per-mode selector verdicts (single iteration)
    pub per_mode: [Selection; 3],
    /// decision-table hits across the three per-mode selector calls
    pub cache_hits: usize,
    /// decision-table misses across the three per-mode selector calls
    pub cache_misses: usize,
}

/// Simulate ReFacTo's communication with per-mode auto-selection: each
/// mode's count vector gets its own (library, algorithm) argmin — the
/// three modes of one data set can legitimately pick different winners
/// (the paper's "no single library wins" finding, taken to its
/// per-call conclusion). Selections go through the decision-table
/// cache ([`AlgoSelector::select`]): a mode whose (system, ranks,
/// irregularity bucket) key repeats re-simulates only the shortlist,
/// and the verdict carries `cached = true`; the table statistics ride
/// along in the report.
pub fn refacto_comm_auto(
    topo: &Topology,
    params: Params,
    spec: &TensorSpec,
    gpus: usize,
    iters: usize,
) -> AutoRefactoReport {
    assert!(gpus >= 1 && gpus <= topo.num_gpus());
    let mut selector = AlgoSelector::new(params);
    let counts = mode_counts(spec, gpus);
    let per_mode = [
        selector.select(topo, &counts[0]),
        selector.select(topo, &counts[1]),
        selector.select(topo, &counts[2]),
    ];
    let (cache_hits, cache_misses) = selector.cache_stats();
    let once: f64 = per_mode.iter().map(|s| s.time).sum();
    AutoRefactoReport {
        dataset: spec.name,
        gpus,
        iters,
        total_time: once * iters as f64,
        per_mode,
        cache_hits,
        cache_misses,
    }
}

/// The multi-tenant verdict on ReFacTo's communication: the refacto
/// op stream run as one tenant among synthetic background tenants on
/// a shared fabric (DESIGN.md §9).
#[derive(Clone, Debug)]
pub struct ContendedRefacto {
    /// Data-set name (Table I).
    pub dataset: &'static str,
    /// Simulated GPU (rank) count.
    pub gpus: usize,
    /// Synthetic background tenants sharing the fabric.
    pub background: usize,
    /// CP-ALS iterations (3 Allgatherv ops each).
    pub iters: usize,
    /// Completion of the refacto tenant alone on the fabric (seconds).
    pub isolated: f64,
    /// Completion of the refacto tenant among the background tenants.
    pub contended: f64,
    /// contended / isolated.
    pub slowdown: f64,
    /// p99 of the refacto tenant's contended per-op latencies.
    pub p99_latency: f64,
}

/// Knobs of the contended-refacto study (grouped so the hook's
/// signature stays small).
#[derive(Clone, Copy, Debug)]
pub struct ContentionCfg {
    /// Simulated GPU (rank) count.
    pub gpus: usize,
    /// CP-ALS iterations the refacto tenant replays (3 ops each).
    pub iters: usize,
    /// Synthetic background tenants sharing the fabric.
    pub background: usize,
    /// Workload seed (arrival jitter, distribution draws).
    pub seed: u64,
}

/// Build the refacto-vs-background workload spec: tenant 0 replays the
/// data set's per-mode Allgatherv trace back-to-back (`3 x iters`
/// ops); each background tenant draws OSU-distribution vectors of the
/// foreground's mean op volume with staggered, jittered arrivals.
pub fn refacto_workload_spec(
    spec: &TensorSpec,
    lib: crate::workload::TenantLib,
    cfg: &ContentionCfg,
) -> crate::workload::WorkloadSpec {
    use crate::osu::distributions::Distribution;
    use crate::workload::spec::{SYNTHETIC_GAP, SYNTHETIC_JITTER, SYNTHETIC_STAGGER};
    use crate::workload::{OpStream, TenantSpec, WorkloadSpec};

    let counts = mode_counts(spec, cfg.gpus);
    let volume_per_op: u64 =
        counts.iter().map(|c| c.iter().sum::<u64>()).sum::<u64>() / 3;
    let mut tenants = vec![TenantSpec::immediate(
        "refacto",
        0,
        lib.clone(),
        OpStream::TensorModes { spec: spec.clone(), gpus: cfg.gpus },
        3 * cfg.iters,
    )];
    let dists = Distribution::all();
    for i in 0..cfg.background {
        tenants.push(TenantSpec {
            name: format!("bg-{i}"),
            seed: 1 + i as u64,
            lib: lib.clone(),
            op: crate::comm::collective::CollectiveOp::Allgatherv,
            stream: OpStream::Distribution {
                dist: dists[i % dists.len()],
                gpus: cfg.gpus,
                total: volume_per_op.max(1),
            },
            ops: 3 * cfg.iters,
            start_offset: (i + 1) as f64 * SYNTHETIC_STAGGER,
            gap: SYNTHETIC_GAP,
            jitter: SYNTHETIC_JITTER,
        });
    }
    WorkloadSpec {
        name: format!("refacto-{}+{}bg", spec.name, cfg.background),
        seed: cfg.seed,
        tenants,
        faults: Vec::new(),
    }
}

/// Run the refacto communication pattern as one tenant among
/// `cfg.background` synthetic tenants; reports idle-vs-contended
/// tenant completion through the shared-fabric workload engine.
pub fn refacto_comm_contended(
    topo: &Topology,
    lib: crate::workload::TenantLib,
    params: Params,
    spec: &TensorSpec,
    cfg: &ContentionCfg,
) -> ContendedRefacto {
    assert!(cfg.gpus >= 1 && cfg.gpus <= topo.num_gpus());
    assert!(cfg.iters >= 1);
    let full = refacto_workload_spec(spec, lib, cfg);
    let alone = crate::workload::WorkloadSpec {
        name: full.name.clone(),
        seed: full.seed,
        tenants: vec![full.tenants[0].clone()],
        faults: full.faults.clone(),
    };
    // plan once; the foreground tenant's plan is removal-invariant, so
    // the isolated replay reuses it instead of re-running an auto
    // tenant's selector simulations
    let plans = crate::workload::engine::plan(topo, &full, params)
        .expect("refacto workload spec is valid by construction");
    let contended = crate::workload::engine::run_planned(topo, &full, params, &plans);
    let alone_plans = vec![plans[0].clone()];
    let isolated = crate::workload::engine::run_planned(topo, &alone, params, &alone_plans);
    let (c, i) = (&contended.tenants[0], &isolated.tenants[0]);
    ContendedRefacto {
        dataset: spec.name,
        gpus: cfg.gpus,
        background: cfg.background,
        iters: cfg.iters,
        isolated: i.completion,
        contended: c.completion,
        slowdown: c.completion / i.completion,
        p99_latency: c.latency_percentile(99.0),
    }
}

/// The degraded-fabric verdict on ReFacTo's communication: every mode's
/// Allgatherv simulated healthy and under a fault set (DESIGN.md §12).
#[derive(Clone, Debug)]
pub struct DegradedRefacto {
    /// Data-set name (Table I).
    pub dataset: &'static str,
    /// Library that ran the collectives.
    pub library: Library,
    /// Simulated GPU (rank) count.
    pub gpus: usize,
    /// CP-ALS iterations the totals cover.
    pub iters: usize,
    /// Total communication time on the healthy fabric (seconds).
    pub healthy_total: f64,
    /// Total communication time on the degraded fabric (seconds).
    pub degraded_total: f64,
    /// degraded / healthy.
    pub slowdown: f64,
    /// Per-mode single-iteration times, healthy fabric.
    pub per_mode_healthy: [f64; 3],
    /// Per-mode single-iteration times, degraded fabric.
    pub per_mode_degraded: [f64; 3],
}

/// Simulate ReFacTo's communication on a **degraded** fabric: each
/// mode's Allgatherv runs once healthy (exactly [`refacto_comm`]) and
/// once with the perturbation set's capacity steps applied
/// ([`crate::perturb::perturbed_allgatherv`] — the same compose path,
/// so an empty set reproduces the healthy numbers bit-for-bit). This is
/// what `agv refacto --perturb` and the `agv faults` tables surface.
pub fn refacto_comm_degraded(
    topo: &Topology,
    lib: Library,
    params: Params,
    spec: &TensorSpec,
    gpus: usize,
    iters: usize,
    perts: &[crate::perturb::Perturbation],
) -> DegradedRefacto {
    assert!(gpus >= 1 && gpus <= topo.num_gpus());
    let counts = mode_counts(spec, gpus);
    let library = lib.build(params);
    let mut per_mode_healthy = [0.0f64; 3];
    let mut per_mode_degraded = [0.0f64; 3];
    for (m, c) in counts.iter().enumerate() {
        per_mode_healthy[m] = library.allgatherv(topo, c).time;
        per_mode_degraded[m] =
            crate::perturb::perturbed_allgatherv(topo, lib, params, c, perts).time;
    }
    let healthy_total: f64 = per_mode_healthy.iter().sum::<f64>() * iters as f64;
    let degraded_total: f64 = per_mode_degraded.iter().sum::<f64>() * iters as f64;
    DegradedRefacto {
        dataset: spec.name,
        library: lib,
        gpus,
        iters,
        healthy_total,
        degraded_total,
        slowdown: degraded_total / healthy_total,
        per_mode_healthy,
        per_mode_degraded,
    }
}

/// Sweep `MV2_GPUDIRECT_LIMIT` for one configuration (paper §V-C): the
/// MPI-CUDA library is rebuilt per value; returns (limit, total time).
///
/// Limits fan out over the bounded worker pool — each point is an
/// independent pure simulation, and the scoped pool lets the jobs
/// borrow `topo`/`spec` directly.
pub fn gdr_limit_sweep(
    topo: &Topology,
    spec: &TensorSpec,
    gpus: usize,
    iters: usize,
    limits: &[u64],
) -> Vec<(u64, f64)> {
    let jobs: Vec<_> = limits
        .iter()
        .map(|&limit| {
            move || {
                let params = Params::default().with_gpudirect_limit(limit);
                let r = refacto_comm(topo, Library::MpiCuda, params, spec, gpus, iters);
                (limit, r.total_time)
            }
        })
        .collect();
    crate::util::pool::parallel_map(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::datasets;
    use crate::topology::systems::{cluster, dgx1, SystemKind};

    #[test]
    fn totals_scale_with_iterations() {
        let topo = dgx1();
        let d = datasets::netflix();
        let one = refacto_comm(&topo, Library::Nccl, Params::default(), &d, 8, 1);
        let ten = refacto_comm(&topo, Library::Nccl, Params::default(), &d, 8, 10);
        assert!((ten.total_time - 10.0 * one.total_time).abs() < 1e-9);
    }

    #[test]
    fn nccl_dgx1_beats_cluster_on_tensors() {
        // headline: "NCCL on the DGX-1 is up to 4.7x faster than NCCL on
        // the cluster" for the tensor workloads.
        let dgx = dgx1();
        let clu = cluster(16);
        let mut best = 0.0f64;
        for d in datasets::all() {
            let a = refacto_comm(&dgx, Library::Nccl, Params::default(), &d, 8, 1);
            let b = refacto_comm(&clu, Library::Nccl, Params::default(), &d, 8, 1);
            assert!(b.total_time > a.total_time, "{}", d.name);
            best = best.max(b.total_time / a.total_time);
        }
        assert!(best > 2.0, "max DGX-1 advantage only {best}x");
    }

    #[test]
    fn nccl_competitive_with_mpicuda_on_cluster() {
        // headline: NCCL ~1.2x faster on average than MVAPICH-GDR on the
        // cluster across tensors/GPU counts.
        let clu = cluster(16);
        let mut ratios = Vec::new();
        for d in datasets::all() {
            for gpus in [2usize, 8, 16] {
                let n = refacto_comm(&clu, Library::Nccl, Params::default(), &d, gpus, 1);
                let m = refacto_comm(&clu, Library::MpiCuda, Params::default(), &d, gpus, 1);
                ratios.push(m.total_time / n.total_time);
            }
        }
        let geo = crate::util::stats::geomean(&ratios);
        assert!(geo > 0.9, "NCCL not competitive: geomean advantage {geo}");
    }

    #[test]
    fn nell1_2gpu_contradiction_vs_benchmark() {
        // Fig. 3 vs Fig. 2: on NELL-1 at 2 GPUs NCCL beats MPI-CUDA on
        // the NVLink systems even though the fixed-size benchmark says
        // otherwise (the IPC cliff vs the 729 MB-class block).
        for sys in [SystemKind::Dgx1, SystemKind::CsStorm] {
            let topo = sys.build();
            let d = datasets::nell1();
            let n = refacto_comm(&topo, Library::Nccl, Params::default(), &d, 2, 1);
            let m = refacto_comm(&topo, Library::MpiCuda, Params::default(), &d, 2, 1);
            assert!(
                n.total_time < m.total_time,
                "{}: nccl={} mpicuda={}",
                sys.name(), n.total_time, m.total_time
            );
        }
    }

    #[test]
    fn amazon_2gpu_matches_benchmark_ordering() {
        // ... and AMAZON (regular, sub-cliff messages) keeps the
        // benchmark's ordering (MPI-CUDA wins at 2 GPUs on NVLink).
        let topo = dgx1();
        let d = datasets::amazon();
        let n = refacto_comm(&topo, Library::Nccl, Params::default(), &d, 2, 1);
        let m = refacto_comm(&topo, Library::MpiCuda, Params::default(), &d, 2, 1);
        assert!(m.total_time < n.total_time, "nccl={} mpicuda={}", n.total_time, m.total_time);
    }

    #[test]
    fn auto_never_loses_to_fixed_libraries_on_tensors() {
        // the candidate set contains each library's default, so the
        // per-mode argmin can only match or beat every fixed choice
        let topo = dgx1();
        for d in datasets::all() {
            let auto = refacto_comm_auto(&topo, Params::default(), &d, 8, 1);
            for lib in [Library::Mpi, Library::MpiCuda, Library::Nccl] {
                let fixed = refacto_comm(&topo, lib, Params::default(), &d, 8, 1);
                assert!(
                    auto.total_time <= fixed.total_time,
                    "{}: auto {} slower than {} {}",
                    d.name, auto.total_time, lib.name(), fixed.total_time
                );
            }
        }
    }

    #[test]
    fn auto_totals_scale_with_iterations() {
        let topo = dgx1();
        let d = datasets::netflix();
        let one = refacto_comm_auto(&topo, Params::default(), &d, 8, 1);
        let ten = refacto_comm_auto(&topo, Params::default(), &d, 8, 10);
        assert!((ten.total_time - 10.0 * one.total_time).abs() < 1e-9);
        assert_eq!(
            one.per_mode.map(|s| s.candidate),
            ten.per_mode.map(|s| s.candidate)
        );
    }

    #[test]
    fn contended_refacto_slows_down_but_not_alone() {
        let topo = dgx1();
        let d = datasets::netflix();
        let lib = crate::workload::TenantLib::Fixed(Library::Nccl);
        let cfg = |background| ContentionCfg { gpus: 8, iters: 1, background, seed: 5 };
        let alone = refacto_comm_contended(&topo, lib.clone(), Params::default(), &d, &cfg(0));
        assert_eq!(alone.background, 0);
        assert!(
            (alone.slowdown - 1.0).abs() < 1e-9,
            "no background, yet slowdown {}", alone.slowdown
        );
        // the isolated tenant completion is exactly the back-to-back
        // sum of the three per-mode isolated Allgatherv times
        let fixed = refacto_comm(&topo, Library::Nccl, Params::default(), &d, 8, 1);
        assert!(
            (alone.isolated - fixed.total_time).abs() / fixed.total_time < 1e-9,
            "workload replay {} vs refacto_comm {}", alone.isolated, fixed.total_time
        );
        let busy = refacto_comm_contended(&topo, lib, Params::default(), &d, &cfg(3));
        assert!(busy.slowdown > 1.02, "3 tenants left no trace: {}", busy.slowdown);
        assert!(busy.p99_latency > 0.0);
    }

    #[test]
    fn degraded_refacto_is_healthy_with_no_faults_and_slower_with() {
        let topo = dgx1();
        let d = datasets::netflix();
        let none =
            refacto_comm_degraded(&topo, Library::Nccl, Params::default(), &d, 8, 2, &[]);
        let fixed = refacto_comm(&topo, Library::Nccl, Params::default(), &d, 8, 2);
        assert_eq!(
            none.degraded_total.to_bits(),
            fixed.total_time.to_bits(),
            "empty fault set must reproduce refacto_comm bit-for-bit"
        );
        assert_eq!(none.healthy_total.to_bits(), fixed.total_time.to_bits());
        assert!((none.slowdown - 1.0).abs() < 1e-12);
        let straggler = [crate::perturb::Perturbation::straggler(0, 0.4)];
        let bad = refacto_comm_degraded(
            &topo, Library::Nccl, Params::default(), &d, 8, 2, &straggler,
        );
        assert!(bad.slowdown > 1.1, "straggler left no trace: {}", bad.slowdown);
        for m in 0..3 {
            assert!(bad.per_mode_degraded[m] >= bad.per_mode_healthy[m] * (1.0 - 1e-9));
        }
    }

    #[test]
    fn gdr_sweep_shows_sensitivity() {
        // §V-C: communication runtime is sensitive to MV2_GPUDIRECT_LIMIT
        // on the cluster for DELICIOUS (3.1x between 1MB and 4MB there).
        let topo = cluster(8);
        let d = datasets::delicious();
        let sweep = gdr_limit_sweep(&topo, &d, 8, 1, &[16, 1 << 20, 4 << 20, 512 << 20]);
        let times: Vec<f64> = sweep.iter().map(|&(_, t)| t).collect();
        let max = times.iter().cloned().fold(0.0, f64::max);
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        // Paper reports up to 3.1x on real hardware; our flow-level model
        // reproduces the directional sensitivity (>1.3x swing) — see
        // EXPERIMENTS.md for the measured-vs-paper comparison.
        assert!(max / min > 1.3, "insensitive: {sweep:?}");
        // ... and the best setting at 8 GPUs should be a small limit
        // (stage everything), matching the paper's 16-byte optimum.
        let best = sweep.iter().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
        assert!(best.0 <= 4 << 20, "best limit {} unexpectedly large", best.0);
    }
}
