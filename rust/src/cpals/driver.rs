//! End-to-end ReFacTo driver: real CP-ALS numerics through the AOT
//! PJRT executables + simulated multi-GPU communication.
//!
//! Mirrors ReFacTo's structure (paper §III): every (simulated) GPU rank
//! owns a contiguous slice of each mode (nnz-balanced), computes the
//! MTTKRP rows for its slice, and the factor rows are exchanged with an
//! Allgatherv — here the *numerics* of the gather are an exact sum of the
//! disjoint per-rank partials (see python/tests test_distributed_mttkrp_
//! equals_full), while the *cost* of the gather comes from the simulated
//! communication library on the chosen system topology.

use crate::anyhow;
use crate::comm::select::{AlgoSelector, Selection};
use crate::comm::{Library, Params};
use crate::util::error::Result;
use crate::runtime::{HostTensor, Runtime};
use crate::tensor::datasets::ROW_BYTES;
use crate::tensor::partition::histogram_boundaries;
use crate::tensor::CooTensor;
use crate::topology::Topology;
use crate::util::prng::Rng;

/// Per-iteration log entry.
#[derive(Clone, Debug)]
pub struct IterLog {
    /// Iteration index (0-based).
    pub iter: usize,
    /// CP fit (1 - relative residual); higher is better.
    pub fit: f64,
    /// wall-clock spent in PJRT compute this iteration (real, measured)
    pub compute_secs: f64,
    /// simulated communication time this iteration (per library)
    pub comm_secs: Vec<(Library, f64)>,
}

/// Result of one end-to-end factorization run.
#[derive(Clone, Debug)]
pub struct DriverReport {
    /// Artifact config the run used ("small" / "e2e").
    pub config: String,
    /// Simulated GPU count.
    pub gpus: usize,
    /// Padded tensor dimensions from the artifact.
    pub dims: [usize; 3],
    /// Actual (unpadded) nonzero count.
    pub nnz: usize,
    /// Decomposition rank R.
    pub rank: usize,
    /// Per-iteration fit/compute/comm log.
    pub iters: Vec<IterLog>,
    /// total simulated communication per library
    pub comm_totals: Vec<(Library, f64)>,
    /// auto-selection verdict: per-mode winning (library, algorithm)
    /// and the total auto communication time across iterations
    pub auto_comm: AutoComm,
    /// Total real PJRT compute seconds across iterations.
    pub compute_total: f64,
}

/// The `auto` communication summary of one run: what the selector
/// picked for each mode's count vector, and the resulting total.
#[derive(Clone, Debug)]
pub struct AutoComm {
    /// per-mode selector verdicts (single iteration)
    pub per_mode: [Selection; 3],
    /// total simulated auto communication across all iterations
    pub total: f64,
}

impl DriverReport {
    /// Fit after the last iteration (0.0 if no iterations ran).
    pub fn final_fit(&self) -> f64 {
        self.iters.last().map(|l| l.fit).unwrap_or(0.0)
    }
}

/// Factorization state: the replicated factor matrices (every rank holds
/// full copies, as in ReFacTo/DFacTo).
struct State {
    fa: Vec<f32>,
    fb: Vec<f32>,
    fc: Vec<f32>,
    lam: Vec<f32>,
}

/// One rank's padded COO slice for one mode, in artifact argument order.
struct ModeSlice {
    vals: Vec<f32>,
    rows: Vec<i32>,
    cols_b: Vec<i32>,
    cols_c: Vec<i32>,
}

/// Extract rank slices for a mode: nonzeros whose mode index falls in
/// [bounds[r], bounds[r+1]), padded to `n_pad` with zero entries.
fn mode_slices(t: &CooTensor, mode: usize, bounds: &[u64], n_pad: usize) -> Vec<ModeSlice> {
    let ranks = bounds.len() - 1;
    let mut out: Vec<ModeSlice> = (0..ranks)
        .map(|_| ModeSlice {
            vals: Vec::new(),
            rows: Vec::new(),
            cols_b: Vec::new(),
            cols_c: Vec::new(),
        })
        .collect();
    for n in 0..t.nnz() {
        let (i, j, k) = (t.i[n], t.j[n], t.k[n]);
        let (row, cb, cc) = match mode {
            0 => (i, j, k),
            1 => (j, i, k),
            2 => (k, i, j),
            _ => unreachable!(),
        };
        // bounds are few (<= 16): linear scan
        let r = (0..ranks)
            .find(|&r| (row as u64) < bounds[r + 1])
            .expect("index beyond last bound");
        let s = &mut out[r];
        s.vals.push(t.vals[n]);
        s.rows.push(row as i32);
        s.cols_b.push(cb as i32);
        s.cols_c.push(cc as i32);
    }
    for s in out.iter_mut() {
        assert!(s.vals.len() <= n_pad, "slice exceeds padded size");
        s.vals.resize(n_pad, 0.0);
        s.rows.resize(n_pad, 0);
        s.cols_b.resize(n_pad, 0);
        s.cols_c.resize(n_pad, 0);
    }
    out
}

/// Driver configuration.
pub struct Driver<'t> {
    /// PJRT runtime holding the AOT artifacts.
    pub runtime: Runtime,
    /// Artifact config suffix ("small" / "e2e").
    pub config: String,
    /// System the communication is simulated on.
    pub topo: &'t Topology,
    /// Simulated GPU (rank) count.
    pub gpus: usize,
    /// Libraries whose communication time is simulated per iteration.
    pub libraries: Vec<Library>,
    /// Protocol parameters for the simulated libraries.
    pub params: Params,
}

impl<'t> Driver<'t> {
    /// Assemble a driver; communication params default.
    pub fn new(
        runtime: Runtime,
        config: &str,
        topo: &'t Topology,
        gpus: usize,
        libraries: Vec<Library>,
    ) -> Driver<'t> {
        Driver {
            runtime,
            config: config.to_string(),
            topo,
            gpus,
            libraries,
            params: Params::default(),
        }
    }

    fn art(&self, base: &str) -> String {
        format!("{base}_{}", self.config)
    }

    /// Shapes from the als_sweep artifact: (dims, nnz, rank).
    pub fn shapes(&self) -> Result<([usize; 3], usize, usize)> {
        let meta = self
            .runtime
            .meta(&self.art("als_sweep"))
            .ok_or_else(|| anyhow!("missing artifact als_sweep_{}", self.config))?;
        let n = meta.inputs[0].shape[0];
        let i = meta.outputs[0].shape[0];
        let j = meta.outputs[1].shape[0];
        let k = meta.outputs[2].shape[0];
        let r = meta.outputs[0].shape[1];
        Ok(([i, j, k], n, r))
    }

    /// Run the distributed factorization on a materialized tensor.
    pub fn run(&mut self, tensor: &CooTensor, iters: usize, seed: u64) -> Result<DriverReport> {
        let ([di, dj, dk], n_pad, rank) = self.shapes()?;
        assert!(tensor.nnz() <= n_pad, "tensor larger than artifact nnz");
        assert!(
            tensor.dims[0] as usize <= di
                && tensor.dims[1] as usize <= dj
                && tensor.dims[2] as usize <= dk,
            "tensor dims exceed artifact dims"
        );
        let p = self.gpus;

        // DFacTo partition per mode (exact histograms on padded dims).
        let bounds: Vec<Vec<u64>> = (0..3)
            .map(|m| {
                let mut h = tensor.mode_histogram(m);
                h.resize([di, dj, dk][m], 0); // padded rows carry no nnz
                histogram_boundaries(&h, p)
            })
            .collect();
        // Per-mode per-rank slices (static padded shapes).
        let slices: Vec<Vec<ModeSlice>> =
            (0..3).map(|m| mode_slices(tensor, m, &bounds[m], n_pad)).collect();
        // Per-mode Allgatherv counts (bytes).
        let counts: Vec<Vec<u64>> = bounds
            .iter()
            .map(|b| b.windows(2).map(|w| (w[1] - w[0]) * ROW_BYTES).collect())
            .collect();

        // Padded full COO (rank 0's copy) for the fit computation.
        let full = crate::tensor::synth::pad_coo(tensor, n_pad);
        let to_i32 = |v: &[u32]| v.iter().map(|&x| x as i32).collect::<Vec<i32>>();
        let (fi, fj, fk) = (to_i32(&full.i), to_i32(&full.j), to_i32(&full.k));
        let norm_x_sq = full.norm_sq() as f32;

        // Random initial factors (replicated).
        let mut rng = Rng::new(seed);
        let mut init = |rows: usize| -> Vec<f32> {
            (0..rows * rank).map(|_| rng.normal() as f32 * 0.3).collect()
        };
        let mut state = State {
            fa: init(di),
            fb: init(dj),
            fc: init(dk),
            lam: vec![1.0; rank],
        };

        // Pre-simulate the per-mode communication once per library (the
        // partition is static, so every iteration costs the same).
        let mut comm_once: Vec<(Library, [f64; 3])> = Vec::new();
        for &lib in &self.libraries {
            let l = lib.build(self.params);
            let mut per = [0.0f64; 3];
            for m in 0..3 {
                per[m] = l.allgatherv(self.topo, &counts[m]).time;
            }
            comm_once.push((lib, per));
        }
        // ... and the auto-selection verdict per mode.
        let selector = AlgoSelector::new(self.params);
        let auto_per_mode = [
            selector.select_fresh(self.topo, &counts[0]),
            selector.select_fresh(self.topo, &counts[1]),
            selector.select_fresh(self.topo, &counts[2]),
        ];
        let auto_once: f64 = auto_per_mode.iter().map(|s| s.time).sum();

        let mut logs = Vec::new();
        let mut compute_total = 0.0;
        for iter in 0..iters {
            let t0 = std::time::Instant::now();
            for mode in 0..3 {
                self.update_mode(mode, &slices[mode], &mut state, [di, dj, dk])?;
            }
            // fit on the gathered (replicated) factors
            let fit = self.fit(&full, &fi, &fj, &fk, norm_x_sq, &state)?;
            let compute_secs = t0.elapsed().as_secs_f64();
            compute_total += compute_secs;
            let comm_secs: Vec<(Library, f64)> = comm_once
                .iter()
                .map(|(l, per)| (*l, per.iter().sum()))
                .collect();
            logs.push(IterLog { iter, fit, compute_secs, comm_secs });
        }

        let comm_totals = comm_once
            .iter()
            .map(|(l, per)| (*l, per.iter().sum::<f64>() * iters as f64))
            .collect();
        Ok(DriverReport {
            config: self.config.clone(),
            gpus: p,
            dims: [di, dj, dk],
            nnz: tensor.nnz(),
            rank,
            iters: logs,
            comm_totals,
            auto_comm: AutoComm {
                per_mode: auto_per_mode,
                total: auto_once * iters as f64,
            },
            compute_total,
        })
    }

    /// One mode update: per-rank MTTKRP partials -> "Allgatherv" (exact
    /// sum of disjoint rows) -> post-collective factor update.
    fn update_mode(
        &mut self,
        mode: usize,
        slices: &[ModeSlice],
        state: &mut State,
        dims: [usize; 3],
    ) -> Result<()> {
        let rank_dim = dims[mode];
        let r = state.lam.len();
        let (fb, fc) = match mode {
            0 => (state.fb.clone(), state.fc.clone()),
            1 => (state.fa.clone(), state.fc.clone()),
            2 => (state.fa.clone(), state.fb.clone()),
            _ => unreachable!(),
        };
        let mttkrp_name = self.art(&format!("mttkrp_mode{mode}"));
        let mut m_full = vec![0.0f32; rank_dim * r];
        for slice in slices {
            let outs = self.runtime.execute(
                &mttkrp_name,
                &[
                    HostTensor::F32(slice.vals.clone()),
                    HostTensor::I32(slice.rows.clone()),
                    HostTensor::I32(slice.cols_b.clone()),
                    HostTensor::I32(slice.cols_c.clone()),
                    HostTensor::F32(fb.clone()),
                    HostTensor::F32(fc.clone()),
                ],
            )?;
            let part = outs[0].as_f32()?;
            for (acc, &x) in m_full.iter_mut().zip(part) {
                *acc += x;
            }
        }
        let update_name = self.art(&format!("update_post_mode{mode}"));
        let outs = self.runtime.execute(
            &update_name,
            &[HostTensor::F32(m_full), HostTensor::F32(fb), HostTensor::F32(fc)],
        )?;
        let new_factor = outs[0].as_f32()?.to_vec();
        let lam = outs[1].as_f32()?.to_vec();
        match mode {
            0 => state.fa = new_factor,
            1 => state.fb = new_factor,
            2 => state.fc = new_factor,
            _ => unreachable!(),
        }
        state.lam = lam;
        Ok(())
    }

    fn fit(
        &mut self,
        full: &CooTensor,
        fi: &[i32],
        fj: &[i32],
        fk: &[i32],
        norm_x_sq: f32,
        state: &State,
    ) -> Result<f64> {
        let outs = self.runtime.execute(
            &self.art("fit"),
            &[
                HostTensor::F32(vec![norm_x_sq]),
                HostTensor::F32(full.vals.clone()),
                HostTensor::I32(fi.to_vec()),
                HostTensor::I32(fj.to_vec()),
                HostTensor::I32(fk.to_vec()),
                HostTensor::F32(state.lam.clone()),
                HostTensor::F32(state.fa.clone()),
                HostTensor::F32(state.fb.clone()),
                HostTensor::F32(state.fc.clone()),
            ],
        )?;
        Ok(outs[0].as_f32()?[0] as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth::random_coo;
    use crate::tensor::{ModeProfile, TensorSpec};

    fn spec() -> TensorSpec {
        TensorSpec {
            name: "t",
            modes: [
                ModeProfile { dim: 64, skew: 0.5 },
                ModeProfile { dim: 32, skew: 0.2 },
                ModeProfile { dim: 32, skew: 0.0 },
            ],
            nnz: 512,
        }
    }

    #[test]
    fn mode_slices_partition_all_nonzeros() {
        let t = random_coo(&spec(), 512, 3);
        let mut h = t.mode_histogram(0);
        h.resize(64, 0);
        let bounds = histogram_boundaries(&h, 4);
        let slices = mode_slices(&t, 0, &bounds, 512);
        assert_eq!(slices.len(), 4);
        let total: usize = slices
            .iter()
            .map(|s| s.vals.iter().filter(|&&v| v != 0.0).count())
            .sum();
        // all non-padding entries are assigned exactly once (values are
        // N(0,1); exact zeros have measure ~0)
        assert_eq!(total, t.vals.iter().filter(|&&v| v != 0.0).count());
        // every row index within its rank's bounds
        for (r, s) in slices.iter().enumerate() {
            for (n, &v) in s.vals.iter().enumerate() {
                if v != 0.0 {
                    let row = s.rows[n] as u64;
                    assert!(row >= bounds[r] && row < bounds[r + 1]);
                }
            }
        }
    }

    #[test]
    fn mode_slices_column_order_per_mode() {
        let t = CooTensor {
            dims: [4, 4, 4],
            i: vec![1],
            j: vec![2],
            k: vec![3],
            vals: vec![5.0],
        };
        let b = vec![0u64, 4];
        let s1 = &mode_slices(&t, 1, &b, 4)[0];
        assert_eq!(s1.rows[0], 2);
        assert_eq!(s1.cols_b[0], 1); // mode 1 gathers from (A, C): i, k
        assert_eq!(s1.cols_c[0], 3);
        let s2 = &mode_slices(&t, 2, &b, 4)[0];
        assert_eq!(s2.rows[0], 3);
        assert_eq!(s2.cols_b[0], 1); // mode 2 gathers from (A, B): i, j
        assert_eq!(s2.cols_c[0], 2);
    }
}
