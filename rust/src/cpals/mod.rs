//! ReFacTo: the paper's multi-GPU distributed CP-ALS case study (§III).
//!
//! Two halves:
//! - [`comm_model`]: the Fig. 3 experiment — ReFacTo's communication
//!   runtime (one Allgatherv per mode per iteration with the DFacTo
//!   partition's irregular counts) simulated for every (data set, system,
//!   library, GPU count) combination;
//! - [`driver`]: the end-to-end factorization — real CP-ALS numerics on
//!   simulated GPUs: per-rank MTTKRP through the AOT-compiled PJRT
//!   executables, Allgatherv *timing* from the communication simulator,
//!   fit logged per iteration.

pub mod comm_model;
pub mod driver;

pub use comm_model::{refacto_comm, RefactoReport};
