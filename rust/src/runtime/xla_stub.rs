//! Offline stand-in for the `xla` crate's PJRT bindings (DESIGN.md §6).
//!
//! The runtime's public API (`Runtime::open`, artifact listing, shape
//! metadata) works against this stub — only HLO *compilation and
//! execution* are unavailable, and fail with a clear error naming the
//! missing backend. A build environment that vendors the real
//! `xla`/`xla_extension` crate can swap this module for the genuine
//! bindings without touching `runtime/mod.rs`: the API surface below is
//! the exact subset the runtime calls.

use std::fmt;

/// Error raised by the stubbed XLA operations.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

const UNAVAILABLE: &str = "XLA/PJRT backend not available in this build \
(the offline toolchain vendors no `xla` crate); artifact metadata is \
readable but HLO compilation/execution is not — see DESIGN.md §6";

fn unavailable() -> XlaError {
    XlaError(UNAVAILABLE.to_string())
}

/// Element payload of a [`Literal`].
#[derive(Clone, Debug)]
pub enum LiteralData {
    /// 32-bit floats.
    F32(Vec<f32>),
    /// 32-bit signed integers.
    I32(Vec<i32>),
}

/// Host-side typed array, the PJRT interchange value.
#[derive(Clone, Debug)]
pub struct Literal {
    /// Flat element storage.
    pub data: LiteralData,
    /// Logical dimensions.
    pub dims: Vec<i64>,
}

/// Element types a [`Literal`] can carry.
pub trait Element: Sized {
    /// Wrap a slice into literal storage.
    fn wrap(data: &[Self]) -> LiteralData;
    /// Extract a typed copy if the storage matches `Self`.
    fn extract(data: &LiteralData) -> Option<Vec<Self>>;
}

impl Element for f32 {
    fn wrap(data: &[Self]) -> LiteralData {
        LiteralData::F32(data.to_vec())
    }
    fn extract(data: &LiteralData) -> Option<Vec<Self>> {
        match data {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Element for i32 {
    fn wrap(data: &[Self]) -> LiteralData {
        LiteralData::I32(data.to_vec())
    }
    fn extract(data: &LiteralData) -> Option<Vec<Self>> {
        match data {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: Element>(data: &[T]) -> Literal {
        Literal { data: T::wrap(data), dims: vec![data.len() as i64] }
    }

    /// Reinterpret the literal with new dimensions (element count must
    /// match).
    pub fn reshape(mut self, dims: &[i64]) -> Result<Literal, XlaError> {
        let n: i64 = dims.iter().product();
        let have = match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        };
        if n as usize != have {
            return Err(XlaError(format!(
                "reshape to {dims:?} ({n} elements) from {have} elements"
            )));
        }
        self.dims = dims.to_vec();
        Ok(self)
    }

    /// Typed copy of the elements.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, XlaError> {
        T::extract(&self.data).ok_or_else(|| XlaError("literal dtype mismatch".to_string()))
    }

    /// Destructure a tuple literal (stub: never produced, always errors).
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }
}

/// Stub PJRT client.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the (stub) CPU client. Always succeeds so artifact
    /// metadata can be inspected without a backend.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient { _private: () })
    }

    /// Platform identifier; the stub is explicit about being one.
    pub fn platform_name(&self) -> String {
        "stub (no PJRT backend)".to_string()
    }

    /// Compile a computation (stub: always errors).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub: loading always errors).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file (stub: always errors with the backend
    /// message — the file is not read).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable())
    }
}

/// Computation wrapper accepted by [`PjRtClient::compile`].
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle (stub: never constructed).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments (stub: always errors).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

/// Device-resident result buffer (stub: never constructed).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal (stub: always errors).
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims, vec![4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims, vec![2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        let bad = Literal::vec1(&[1i32, 2]).reshape(&[3]);
        assert!(bad.is_err());
    }

    #[test]
    fn backend_operations_error_clearly() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let err = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("XLA/PJRT backend not available"));
    }
}
