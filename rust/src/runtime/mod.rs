//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the rust hot path. Python never runs here — `make artifacts`
//! produced the `.hlo.txt` files and `meta.json` once at build time.
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax>=0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids — see
//! DESIGN.md §6).
//!
//! This offline build compiles against [`xla_stub`], a faithful stand-in
//! for the `xla` crate's API subset we call: metadata loading and shape
//! inspection work everywhere; compilation/execution require a build
//! that vendors the real PJRT bindings.

mod xla_stub;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{anyhow, bail};

use self::xla_stub as xla;

/// Element type of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

/// Shape + dtype of one artifact input or output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    /// Logical dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: DType,
}

impl TensorMeta {
    /// Total element count (1 for scalars).
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Metadata of one AOT artifact (from meta.json).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Artifact name (meta.json key).
    pub name: String,
    /// HLO text file name inside the artifacts directory.
    pub file: String,
    /// Input shapes/dtypes, in call order.
    pub inputs: Vec<TensorMeta>,
    /// Output shapes/dtypes, in tuple order.
    pub outputs: Vec<TensorMeta>,
}

/// A host-side tensor passed to / returned from an executable.
#[derive(Clone, Debug)]
pub enum HostTensor {
    /// f32 data.
    F32(Vec<f32>),
    /// i32 data.
    I32(Vec<i32>),
}

impl HostTensor {
    /// Element type of this tensor.
    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(_) => DType::F32,
            HostTensor::I32(_) => DType::I32,
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    /// True if the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 data (error if the tensor is i32).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    /// Borrow as i32 data (error if the tensor is f32).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => bail!("expected i32 tensor"),
        }
    }

    fn to_literal(&self, meta: &TensorMeta) -> Result<xla::Literal> {
        if self.len() != meta.elems() {
            bail!(
                "input has {} elements, artifact expects {:?} = {}",
                self.len(), meta.shape, meta.elems()
            );
        }
        if self.dtype() != meta.dtype {
            bail!("input dtype {:?} != artifact dtype {:?}", self.dtype(), meta.dtype);
        }
        let lit = match self {
            HostTensor::F32(v) => xla::Literal::vec1(v),
            HostTensor::I32(v) => xla::Literal::vec1(v),
        };
        let dims: Vec<i64> = meta.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

fn parse_tensor_meta(j: &Json) -> Result<TensorMeta> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = match j.get("dtype").and_then(Json::as_str) {
        Some("f32") => DType::F32,
        Some("i32") => DType::I32,
        other => bail!("unsupported dtype {other:?}"),
    };
    Ok(TensorMeta { shape, dtype })
}

/// The runtime: a PJRT CPU client plus lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    meta: HashMap<String, ArtifactMeta>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open an artifacts directory (must contain meta.json).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} (run `make artifacts`)"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parsing meta.json: {e}"))?;
        let mut meta = HashMap::new();
        for (name, art) in json.as_obj().ok_or_else(|| anyhow!("meta.json not an object"))? {
            let file = art
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name}: missing file"))?
                .to_string();
            let parse_list = |key: &str| -> Result<Vec<TensorMeta>> {
                art.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact {name}: missing {key}"))?
                    .iter()
                    .map(parse_tensor_meta)
                    .collect()
            };
            meta.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file,
                    inputs: parse_list("inputs")?,
                    outputs: parse_list("outputs")?,
                },
            );
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, meta, executables: HashMap::new() })
    }

    /// Artifact names available.
    pub fn artifacts(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.meta.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Metadata of one artifact, if present.
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.meta.get(name)
    }

    /// PJRT platform name ("cpu" on real builds, a stub marker here).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an artifact now (otherwise compiled on first execute).
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .meta
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?;
        let path = self.dir.join(&meta.file);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with host inputs; returns host outputs.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.ensure_compiled(name)?;
        let meta = self.meta.get(name).unwrap().clone();
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact `{name}` takes {} inputs, got {}",
                meta.inputs.len(), inputs.len()
            );
        }
        let literals = inputs
            .iter()
            .zip(&meta.inputs)
            .enumerate()
            .map(|(i, (t, m))| {
                t.to_literal(m).with_context(|| format!("artifact `{name}` input {i}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let exe = self.executables.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "artifact `{name}` declared {} outputs, produced {}",
                meta.outputs.len(), parts.len()
            );
        }
        parts
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, m)| {
                Ok(match m.dtype {
                    DType::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
                    DType::I32 => HostTensor::I32(lit.to_vec::<i32>()?),
                })
            })
            .collect()
    }
}

/// Default artifacts directory: `$AGV_ARTIFACTS` if set; else the first
/// of `./artifacts` and `./rust/artifacts` that holds a `meta.json`
/// (so `make artifacts` output is found from both the repo root and
/// `rust/`); else `./artifacts` for the error message.
pub fn default_artifacts_dir() -> PathBuf {
    if let Some(p) = std::env::var_os("AGV_ARTIFACTS") {
        return PathBuf::from(p);
    }
    for candidate in ["artifacts", "rust/artifacts"] {
        let dir = PathBuf::from(candidate);
        if dir.join("meta.json").exists() {
            return dir;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_meta_elems() {
        let m = TensorMeta { shape: vec![128, 16], dtype: DType::F32 };
        assert_eq!(m.elems(), 2048);
        let s = TensorMeta { shape: vec![], dtype: DType::F32 };
        assert_eq!(s.elems(), 1);
    }

    #[test]
    fn host_tensor_checks() {
        let t = HostTensor::F32(vec![1.0; 4]);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.len(), 4);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        let bad = t.to_literal(&TensorMeta { shape: vec![8], dtype: DType::F32 });
        assert!(bad.is_err());
        let badt = t.to_literal(&TensorMeta { shape: vec![4], dtype: DType::I32 });
        assert!(badt.is_err());
        let ok = t.to_literal(&TensorMeta { shape: vec![2, 2], dtype: DType::F32 });
        assert!(ok.is_ok());
    }
}
